//! The metrics registry: one fixed counter/histogram taxonomy for every
//! layer (solver, adjoint, tape, serving), so NFE/accept accounting lives
//! in exactly one place and cannot double-count across paths.
//!
//! Counters are monotonic `u64` adds and histograms are fixed-bucket
//! log₂ tallies, so merging per-shard registries is an elementwise sum —
//! associative and commutative — and the merged registry is bit-identical
//! at any thread count by construction.

use crate::solvers::SolveStats;
use crate::util::json::Json;

/// The monotonic counters.  [`Registry::absorb_solve_stats`] is the one
/// sanctioned fold from per-trajectory [`SolveStats`] into `Nfe` /
/// `Accepted` / `Rejected`: the solver layer counts at retirement and no
/// other layer re-counts (the "one counter taxonomy" invariant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Model evaluations, folded from retired trajectories' stats.
    Nfe,
    /// Accepted solver steps (same fold).
    Accepted,
    /// Rejected solver steps (same fold).
    Rejected,
    /// Rows admitted into a stepper's working set.
    Admitted,
    /// Rows retired from a stepper's working set.
    Retired,
    /// Requests that exhausted their deadline budget (serving layer).
    DeadlineMiss,
    /// Reverse-mode stage VJP invocations (adjoint layer).
    StageVjps,
    /// Tape nodes allocated across stage VJPs (adjoint layer).
    TapeNodes,
    /// Tape arena bytes touched across stage VJPs (adjoint layer).
    TapeBytes,
}

impl Counter {
    pub const ALL: [Counter; 9] = [
        Counter::Nfe,
        Counter::Accepted,
        Counter::Rejected,
        Counter::Admitted,
        Counter::Retired,
        Counter::DeadlineMiss,
        Counter::StageVjps,
        Counter::TapeNodes,
        Counter::TapeBytes,
    ];

    /// Canonical wire name (JSON exports, tables, MetricsLog columns).
    pub fn name(self) -> &'static str {
        match self {
            Counter::Nfe => "nfe",
            Counter::Accepted => "accepted",
            Counter::Rejected => "rejected",
            Counter::Admitted => "admitted",
            Counter::Retired => "retired",
            Counter::DeadlineMiss => "deadline_miss",
            Counter::StageVjps => "stage_vjps",
            Counter::TapeNodes => "tape_nodes",
            Counter::TapeBytes => "tape_bytes",
        }
    }
}

/// The fixed log₂ histograms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Accepted step magnitudes `|h|`.
    StepSize,
    /// Per-attempt embedded error norms.
    ErrNorm,
    /// Admission-wave sizes (serving layer).
    AdmitWave,
    /// Queue depth per engine step (serving layer).
    QueueDepth,
    /// Admit→retire latency in engine steps per request (serving layer).
    LatencySteps,
    /// Tape node count per stage VJP (adjoint layer).
    TapeNodes,
    /// Tape arena bytes per stage VJP (adjoint layer).
    TapeBytes,
}

impl Hist {
    pub const ALL: [Hist; 7] = [
        Hist::StepSize,
        Hist::ErrNorm,
        Hist::AdmitWave,
        Hist::QueueDepth,
        Hist::LatencySteps,
        Hist::TapeNodes,
        Hist::TapeBytes,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Hist::StepSize => "step_size",
            Hist::ErrNorm => "err_norm",
            Hist::AdmitWave => "admit_wave",
            Hist::QueueDepth => "queue_depth",
            Hist::LatencySteps => "latency_steps",
            Hist::TapeNodes => "tape_nodes",
            Hist::TapeBytes => "tape_bytes",
        }
    }
}

/// A fixed-bucket log₂ histogram: bucket index is the IEEE-754 biased
/// exponent of `|v|` as an `f32`, so bucket `i` tallies values with
/// `floor(log₂|v|) == i − 127` (bucket 0 holds zero/subnormals, bucket
/// 255 non-finite values).  Bucketing is pure bit arithmetic — no float
/// comparisons, no allocation — so observation order never matters and
/// merged histograms are exact sums.
#[derive(Clone, PartialEq, Eq)]
pub struct Log2Hist {
    buckets: [u64; 256],
}

impl Default for Log2Hist {
    fn default() -> Log2Hist {
        Log2Hist { buckets: [0u64; 256] }
    }
}

impl Log2Hist {
    pub fn new() -> Log2Hist {
        Log2Hist::default()
    }

    #[inline]
    pub fn observe(&mut self, v: f32) {
        let idx = ((v.abs().to_bits() >> 23) & 0xff) as usize;
        self.buckets[idx] += 1;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Observations in the bucket for `floor(log₂|v|) == e`.
    pub fn bucket(&self, e: i32) -> u64 {
        let idx = e + 127;
        if (0..=255).contains(&idx) {
            self.buckets[idx as usize]
        } else {
            0
        }
    }

    pub fn absorb(&mut self, other: &Log2Hist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
    }

    /// Non-empty buckets as `[log2, count]` pairs, ascending.
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for (i, c) in self.buckets.iter().enumerate() {
            if *c > 0 {
                arr.push(Json::Arr(vec![
                    Json::Num(i as f64 - 127.0),
                    Json::Num(*c as f64),
                ]));
            }
        }
        Json::Arr(arr)
    }
}

/// A fixed-size counter + histogram set; see the module docs.
#[derive(Clone, Default, PartialEq, Eq)]
pub struct Registry {
    counters: [u64; Counter::ALL.len()],
    hists: [Log2Hist; Hist::ALL.len()],
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    #[inline]
    pub fn inc(&mut self, c: Counter, by: u64) {
        self.counters[c as usize] += by;
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    #[inline]
    pub fn observe(&mut self, h: Hist, v: f32) {
        self.hists[h as usize].observe(v);
    }

    pub fn hist(&self, h: Hist) -> &Log2Hist {
        &self.hists[h as usize]
    }

    /// The one fold from solver stats into the counter taxonomy: called at
    /// trajectory retirement (and nowhere else, so nothing double-counts).
    pub fn absorb_solve_stats(&mut self, s: &SolveStats) {
        self.inc(Counter::Nfe, s.nfe as u64);
        self.inc(Counter::Accepted, s.accepted as u64);
        self.inc(Counter::Rejected, s.rejected as u64);
    }

    /// Elementwise merge (used when per-shard registries join in fixed
    /// shard order; sums are order-independent anyway).
    pub fn absorb(&mut self, other: &Registry) {
        for (a, b) in self.counters.iter_mut().zip(&other.counters) {
            *a += *b;
        }
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.absorb(b);
        }
    }

    /// `{"counters": {...}, "hists": {name: [[log2, count], ...]}}` with
    /// zero entries omitted — the registry's canonical JSON form.
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::new();
        for c in Counter::ALL {
            if self.get(c) > 0 {
                counters.push((c.name(), Json::Num(self.get(c) as f64)));
            }
        }
        let mut hists = Vec::new();
        for h in Hist::ALL {
            if self.hist(h).count() > 0 {
                hists.push((h.name(), self.hist(h).to_json()));
            }
        }
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("hists", Json::obj(hists)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_buckets_land_on_the_exponent() {
        let mut h = Log2Hist::new();
        h.observe(1.0); // 2^0
        h.observe(1.5); // still 2^0
        h.observe(0.25); // 2^-2
        h.observe(-0.25); // magnitude bucketing
        h.observe(1024.0); // 2^10
        h.observe(0.0); // zero bucket
        assert_eq!(h.bucket(0), 2);
        assert_eq!(h.bucket(-2), 2);
        assert_eq!(h.bucket(10), 1);
        assert_eq!(h.bucket(-127), 1);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn registry_merge_is_an_elementwise_sum() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.inc(Counter::Nfe, 3);
        b.inc(Counter::Nfe, 4);
        b.inc(Counter::Retired, 1);
        a.observe(Hist::StepSize, 0.5);
        b.observe(Hist::StepSize, 0.5);
        a.absorb(&b);
        assert_eq!(a.get(Counter::Nfe), 7);
        assert_eq!(a.get(Counter::Retired), 1);
        assert_eq!(a.hist(Hist::StepSize).bucket(-1), 2);
    }

    #[test]
    fn solve_stats_fold_hits_the_three_counters() {
        let mut r = Registry::new();
        let s = SolveStats { nfe: 10, accepted: 3, rejected: 1, h_final: 0.1 };
        r.absorb_solve_stats(&s);
        assert_eq!(r.get(Counter::Nfe), 10);
        assert_eq!(r.get(Counter::Accepted), 3);
        assert_eq!(r.get(Counter::Rejected), 1);
    }

    #[test]
    fn json_form_omits_zero_entries() {
        let mut r = Registry::new();
        r.inc(Counter::Admitted, 2);
        r.observe(Hist::AdmitWave, 2.0);
        let j = r.to_json();
        let c = j.req("counters").unwrap();
        assert_eq!(c.req("admitted").unwrap().as_f64(), Some(2.0));
        assert!(c.get("nfe").is_none());
        let hist = j.req("hists").unwrap().req("admit_wave").unwrap();
        assert_eq!(hist.to_string(), "[[1,1]]");
    }
}
