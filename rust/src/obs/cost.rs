//! Per-trajectory solve-cost attribution: who pays the NFE, and why.
//!
//! The batched adaptive driver already records everything a cost analysis
//! needs — per-attempt `accept`/`reject` instants (embedded error ratio
//! and realized `|h|` per attempt, on the trajectory's own track) and one
//! `traj` span per retirement carrying the [`SolveStats`] totals.  A
//! [`CostLedger`] folds that stream into one [`TrajCost`] row per
//! trajectory:
//!
//! * **NFE / accept / reject attribution** — which trajectories consume
//!   the evaluation budget;
//! * **rejection-streak clustering** — maximal runs of consecutive
//!   rejects, the controller's thrash signature (a stiff region shows up
//!   as long streaks, a marginal tolerance as many short ones);
//! * **a deterministic stiffness proxy** — `Σ err / Σ |h|` over accepted
//!   attempts, i.e. the mean embedded-error ratio × the realized step
//!   density (steps per unit integration time).  Stiff trajectories run
//!   their controller pinned near the accept boundary at tiny steps, so
//!   the proxy grows with stiffness while using no wall clock and no
//!   solver internals beyond what the PI controller already computed.
//!
//! [`RkNfeTable`] is the paper-facing summary: per λ, the correlation
//! between the regularizer the training minimized (`R_K`) and the solve
//! cost it was supposed to buy down (NFE) — the tradeoff of Kelly et al.
//! 2020 made directly measurable (`repro experiment native`).
//!
//! ```
//! use taynode::obs::cost::{CostEvent, CostLedger};
//! let events = vec![
//!     CostEvent::Reject { track: 7, err: 2.5, h: 0.2 },
//!     CostEvent::Accept { track: 7, err: 0.8, h: 0.1 },
//!     CostEvent::Traj { track: 7, attempts: 2, nfe: 14, rejected: 1 },
//! ];
//! let ledger = CostLedger::from_cost_events(events);
//! assert_eq!(ledger.trajs.len(), 1);
//! assert_eq!(ledger.trajs[0].nfe, 14);
//! assert_eq!(ledger.trajs[0].longest_streak, 1);
//! assert!((ledger.trajs[0].stiffness() - 8.0).abs() < 1e-12); // 0.8 / 0.1
//! ```

use crate::obs::{Event, EventKind, Recorder};
use crate::solvers::SolveStats;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::stats::{pearson, spearman};

/// One attributable solver event, decoupled from where it came from: the
/// in-process [`Recorder`] stream ([`CostLedger::from_recorder`]) or a
/// parsed NDJSON trace (`obs::analyze::TraceView::cost_events`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CostEvent {
    /// An accepted attempt: embedded error ratio and realized `|h|`.
    Accept { track: u64, err: f64, h: f64 },
    /// A rejected attempt (the step `|h|` that failed).
    Reject { track: u64, err: f64, h: f64 },
    /// Trajectory retirement totals (the `traj` span).
    Traj { track: u64, attempts: u64, nfe: u64, rejected: u64 },
}

impl CostEvent {
    fn track(&self) -> u64 {
        match self {
            CostEvent::Accept { track, .. }
            | CostEvent::Reject { track, .. }
            | CostEvent::Traj { track, .. } => *track,
        }
    }
}

/// One trajectory's attributed solve cost; see the module docs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrajCost {
    /// Trajectory id (the event track).
    pub id: u64,
    pub nfe: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Maximal runs of consecutive rejects (count of streaks).
    pub reject_streaks: u64,
    /// Longest such run.
    pub longest_streak: u64,
    /// Σ embedded-error ratios over accepted attempts.
    pub sum_err: f64,
    /// Σ realized `|h|` over accepted attempts.
    pub sum_h: f64,
}

impl TrajCost {
    pub fn attempts(&self) -> u64 {
        self.accepted + self.rejected
    }

    /// The deterministic stiffness proxy `Σ err / Σ |h|` (0 when the
    /// trajectory accepted no progress); see the module docs.
    pub fn stiffness(&self) -> f64 {
        if self.sum_h > 0.0 {
            self.sum_err / self.sum_h
        } else {
            0.0
        }
    }
}

/// The per-trajectory cost ledger; rows are sorted by trajectory id, so
/// two ledgers built from differently-chunked recordings of the same
/// solve compare equal.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostLedger {
    pub trajs: Vec<TrajCost>,
    /// Every maximal reject-streak length across all trajectories, in
    /// (trajectory id, chronological) order — the clustering input.
    pub streaks: Vec<u64>,
}

impl CostLedger {
    /// Build from an in-process recorder's event stream.
    pub fn from_recorder(rec: &Recorder) -> CostLedger {
        CostLedger::from_events(rec.events())
    }

    /// Build from raw [`Event`]s (`accept`/`reject` instants and `traj`
    /// spans; everything else is ignored).
    pub fn from_events(events: &[Event]) -> CostLedger {
        let cost = events.iter().filter_map(|e| match (e.name, e.kind) {
            ("accept", EventKind::Instant) => Some(CostEvent::Accept {
                track: e.track,
                err: e.args[0].1,
                h: e.args[1].1,
            }),
            ("reject", EventKind::Instant) => Some(CostEvent::Reject {
                track: e.track,
                err: e.args[0].1,
                h: e.args[1].1,
            }),
            ("traj", EventKind::Span) => Some(CostEvent::Traj {
                track: e.track,
                attempts: e.dur,
                nfe: e.args[0].1 as u64,
                rejected: e.args[1].1 as u64,
            }),
            _ => None,
        });
        CostLedger::from_cost_events(cost)
    }

    /// Build from any [`CostEvent`] stream.  Events are stable-sorted by
    /// track first — each trajectory's events keep their chronological
    /// order (per-attempt instants are stamped by the row's own attempt
    /// counter), so the ledger is identical however the recording was
    /// chunked or interleaved across trajectories.
    pub fn from_cost_events(events: impl IntoIterator<Item = CostEvent>) -> CostLedger {
        let mut evs: Vec<CostEvent> = events.into_iter().collect();
        evs.sort_by_key(CostEvent::track);
        let mut ledger = CostLedger::default();
        let mut cur: Option<TrajCost> = None;
        let mut run = 0u64; // open reject run of the current trajectory
        let flush = |cur: &mut Option<TrajCost>, run: &mut u64, out: &mut CostLedger| {
            if let Some(mut t) = cur.take() {
                if *run > 0 {
                    t.reject_streaks += 1;
                    t.longest_streak = t.longest_streak.max(*run);
                    out.streaks.push(*run);
                    *run = 0;
                }
                out.trajs.push(t);
            }
        };
        for e in evs {
            let track = e.track();
            if cur.as_ref().map(|t| t.id) != Some(track) {
                flush(&mut cur, &mut run, &mut ledger);
                cur = Some(TrajCost { id: track, ..TrajCost::default() });
            }
            let t = match cur.as_mut() {
                Some(t) => t,
                None => continue, // unreachable: cur was just set
            };
            match e {
                CostEvent::Accept { err, h, .. } => {
                    t.accepted += 1;
                    t.sum_err += err;
                    t.sum_h += h;
                    if run > 0 {
                        t.reject_streaks += 1;
                        t.longest_streak = t.longest_streak.max(run);
                        ledger.streaks.push(run);
                        run = 0;
                    }
                }
                CostEvent::Reject { .. } => {
                    t.rejected += 1;
                    run += 1;
                }
                CostEvent::Traj { attempts, nfe, rejected, .. } => {
                    // Retirement totals are authoritative: they cover
                    // attempts made before recording was enabled and the
                    // dead-on-arrival case with no attempt instants.
                    t.nfe = t.nfe.max(nfe);
                    t.rejected = t.rejected.max(rejected);
                    t.accepted = t.accepted.max(attempts.saturating_sub(rejected));
                }
            }
        }
        flush(&mut cur, &mut run, &mut ledger);
        ledger
    }

    /// Ledger-wide totals as a synthetic [`TrajCost`] (id = `u64::MAX`).
    pub fn total(&self) -> TrajCost {
        let mut tot = TrajCost { id: u64::MAX, ..TrajCost::default() };
        for t in &self.trajs {
            tot.nfe += t.nfe;
            tot.accepted += t.accepted;
            tot.rejected += t.rejected;
            tot.reject_streaks += t.reject_streaks;
            tot.longest_streak = tot.longest_streak.max(t.longest_streak);
            tot.sum_err += t.sum_err;
            tot.sum_h += t.sum_h;
        }
        tot
    }

    /// Streak-length clustering: `(length, occurrences)` ascending.
    pub fn streak_hist(&self) -> Vec<(u64, u64)> {
        let mut lens = self.streaks.clone();
        lens.sort_unstable();
        let mut out: Vec<(u64, u64)> = Vec::new();
        for l in lens {
            if matches!(out.last(), Some((len, _)) if *len == l) {
                if let Some((_, n)) = out.last_mut() {
                    *n += 1;
                }
            } else {
                out.push((l, 1));
            }
        }
        out
    }

    /// The `top` most expensive trajectories by NFE (ties broken by id)
    /// plus a `TOTAL` row, as a printable table.
    pub fn table(&self, top: usize) -> Table {
        let mut table = Table::new(&[
            "traj", "nfe", "accepted", "rejected", "streaks", "longest", "stiffness",
        ]);
        let mut order: Vec<usize> = (0..self.trajs.len()).collect();
        order.sort_by_key(|&i| (u64::MAX - self.trajs[i].nfe, self.trajs[i].id));
        for &i in order.iter().take(top) {
            let t = &self.trajs[i];
            table.row(vec![
                t.id.to_string(),
                t.nfe.to_string(),
                t.accepted.to_string(),
                t.rejected.to_string(),
                t.reject_streaks.to_string(),
                t.longest_streak.to_string(),
                format!("{:.4}", t.stiffness()),
            ]);
        }
        let tot = self.total();
        table.row(vec![
            "TOTAL".to_string(),
            tot.nfe.to_string(),
            tot.accepted.to_string(),
            tot.rejected.to_string(),
            tot.reject_streaks.to_string(),
            tot.longest_streak.to_string(),
            format!("{:.4}", tot.stiffness()),
        ]);
        table
    }

    /// Canonical JSON: totals, streak clustering, and the per-trajectory
    /// rows (ascending id).
    pub fn to_json(&self) -> Json {
        let traj_json = |t: &TrajCost| {
            Json::obj(vec![
                ("id", Json::num(t.id as f64)),
                ("nfe", Json::num(t.nfe as f64)),
                ("accepted", Json::num(t.accepted as f64)),
                ("rejected", Json::num(t.rejected as f64)),
                ("reject_streaks", Json::num(t.reject_streaks as f64)),
                ("longest_streak", Json::num(t.longest_streak as f64)),
                ("stiffness", Json::num(t.stiffness())),
            ])
        };
        let tot = self.total();
        Json::obj(vec![
            ("trajectories", Json::num(self.trajs.len() as f64)),
            ("nfe", Json::num(tot.nfe as f64)),
            ("accepted", Json::num(tot.accepted as f64)),
            ("rejected", Json::num(tot.rejected as f64)),
            (
                "streak_hist",
                Json::Arr(
                    self.streak_hist()
                        .iter()
                        .map(|(l, n)| Json::arr_f64(&[*l as f64, *n as f64]))
                        .collect(),
                ),
            ),
            ("trajs", Json::Arr(self.trajs.iter().map(traj_json).collect())),
        ])
    }
}

/// The R_K-vs-NFE correlation table: one row per λ, correlating each
/// trajectory's regularizer quadrature `R_K` against its adaptive-solve
/// NFE (Pearson for the linear link, Spearman for the monotone one).
/// This is the paper's regularizer tradeoff as a measurement: training
/// minimizes `R_K`, serving pays NFE — the correlation says whether one
/// actually predicts the other at each λ.
#[derive(Clone, Debug, Default)]
pub struct RkNfeTable {
    rows: Vec<(f64, Vec<f64>, Vec<f64>)>, // (λ, per-traj R_K, per-traj NFE)
}

impl RkNfeTable {
    pub fn new() -> RkNfeTable {
        RkNfeTable::default()
    }

    /// Add one λ's evaluation: per-trajectory `R_K` and [`SolveStats`]
    /// slices (as produced by the adaptive R_K evaluator).
    pub fn push(&mut self, lambda: f64, r_k: &[f32], stats: &[SolveStats]) {
        let rk: Vec<f64> = r_k.iter().map(|v| *v as f64).collect();
        let nfe: Vec<f64> = stats.iter().map(|s| s.nfe as f64).collect();
        self.rows.push((lambda, rk, nfe));
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// The printable correlation table.
    pub fn table(&self) -> Table {
        let mut table = Table::new(&[
            "lambda", "trajs", "mean R_K", "mean NFE", "pearson", "spearman",
        ]);
        for (lambda, rk, nfe) in &self.rows {
            let n = rk.len().max(1) as f64;
            let mean_rk: f64 = rk.iter().sum::<f64>() / n;
            let mean_nfe: f64 = nfe.iter().sum::<f64>() / n;
            table.row(vec![
                format!("{lambda}"),
                rk.len().to_string(),
                format!("{mean_rk:.3e}"),
                format!("{mean_nfe:.1}"),
                format!("{:.3}", pearson(rk, nfe)),
                format!("{:.3}", spearman(rk, nfe)),
            ]);
        }
        table
    }

    /// Canonical JSON (one object per λ).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|(lambda, rk, nfe)| {
                    let n = rk.len().max(1) as f64;
                    Json::obj(vec![
                        ("lambda", Json::num(*lambda)),
                        ("trajs", Json::num(rk.len() as f64)),
                        ("mean_r_k", Json::num(rk.iter().sum::<f64>() / n)),
                        ("mean_nfe", Json::num(nfe.iter().sum::<f64>() / n)),
                        ("pearson", Json::num(pearson(rk, nfe))),
                        ("spearman", Json::num(spearman(rk, nfe))),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::NO_ARGS;

    #[test]
    fn ledger_attributes_streaks_and_stiffness_per_trajectory() {
        // Trajectory 3: R R A R A — two streaks (2, 1), longest 2.
        // Trajectory 1: A A — no streaks.
        let evs = vec![
            CostEvent::Reject { track: 3, err: 4.0, h: 0.4 },
            CostEvent::Reject { track: 3, err: 2.0, h: 0.2 },
            CostEvent::Accept { track: 3, err: 0.5, h: 0.1 },
            CostEvent::Reject { track: 3, err: 1.5, h: 0.2 },
            CostEvent::Accept { track: 3, err: 0.7, h: 0.1 },
            CostEvent::Traj { track: 3, attempts: 5, nfe: 35, rejected: 3 },
            CostEvent::Accept { track: 1, err: 0.2, h: 0.5 },
            CostEvent::Accept { track: 1, err: 0.4, h: 0.5 },
            CostEvent::Traj { track: 1, attempts: 2, nfe: 14, rejected: 0 },
        ];
        let ledger = CostLedger::from_cost_events(evs);
        assert_eq!(ledger.trajs.len(), 2);
        let (t1, t3) = (&ledger.trajs[0], &ledger.trajs[1]);
        assert_eq!(t1.id, 1);
        assert_eq!((t1.accepted, t1.rejected, t1.nfe), (2, 0, 14));
        assert_eq!(t1.reject_streaks, 0);
        assert!((t1.stiffness() - 0.6).abs() < 1e-12); // (0.2+0.4)/(0.5+0.5)
        assert_eq!(t3.id, 3);
        assert_eq!((t3.accepted, t3.rejected, t3.nfe), (2, 3, 35));
        assert_eq!((t3.reject_streaks, t3.longest_streak), (2, 2));
        assert!((t3.stiffness() - 6.0).abs() < 1e-12); // (0.5+0.7)/0.2
        assert_eq!(ledger.streak_hist(), vec![(1, 1), (2, 1)]);
        assert_eq!(ledger.total().nfe, 49);
    }

    #[test]
    fn ledger_is_chunking_independent() {
        // The same per-trajectory events interleaved two ways (two chunk
        // layouts of a pooled solve) must produce equal ledgers.
        let a = vec![
            CostEvent::Accept { track: 0, err: 0.1, h: 0.2 },
            CostEvent::Reject { track: 2, err: 3.0, h: 0.4 },
            CostEvent::Accept { track: 0, err: 0.3, h: 0.2 },
            CostEvent::Accept { track: 2, err: 0.5, h: 0.2 },
        ];
        let b = vec![a[1], a[3], a[0], a[2]]; // other chunk first
        assert_eq!(
            CostLedger::from_cost_events(a),
            CostLedger::from_cost_events(b)
        );
    }

    #[test]
    fn ledger_reads_recorder_events() {
        let mut rec = Recorder::enabled();
        rec.instant("reject", 5, 0, [("err", 2.0), ("h", 0.3)]);
        rec.instant("accept", 5, 1, [("err", 0.5), ("h", 0.2)]);
        rec.span("traj", 5, 0, 2, [("nfe", 13.0), ("rejected", 1.0)]);
        rec.instant("admit_wave", 0, 0, NO_ARGS); // ignored
        let ledger = CostLedger::from_recorder(&rec);
        assert_eq!(ledger.trajs.len(), 1);
        let t = &ledger.trajs[0];
        assert_eq!((t.id, t.nfe, t.accepted, t.rejected), (5, 13, 1, 1));
        assert_eq!(t.longest_streak, 1);
    }

    #[test]
    fn traj_only_events_still_account() {
        // A trace recorded without per-attempt instants (or a trajectory
        // dead on arrival) still gets its totals from the traj span.
        let ledger = CostLedger::from_cost_events(vec![CostEvent::Traj {
            track: 9,
            attempts: 6,
            nfe: 40,
            rejected: 2,
        }]);
        let t = &ledger.trajs[0];
        assert_eq!((t.nfe, t.accepted, t.rejected), (40, 4, 2));
        assert_eq!(t.stiffness(), 0.0);
    }

    #[test]
    fn table_ranks_by_nfe_with_total_row() {
        let ledger = CostLedger::from_cost_events(vec![
            CostEvent::Traj { track: 0, attempts: 2, nfe: 10, rejected: 0 },
            CostEvent::Traj { track: 1, attempts: 9, nfe: 70, rejected: 2 },
            CostEvent::Traj { track: 2, attempts: 4, nfe: 30, rejected: 1 },
        ]);
        let t = ledger.table(2);
        assert_eq!(t.row_count(), 3); // top 2 + TOTAL
        let text = t.render();
        let first_data_line = text.lines().nth(2).unwrap_or("");
        assert!(first_data_line.trim_start().starts_with('1'), "{text}");
        assert!(text.lines().last().unwrap_or("").contains("TOTAL"), "{text}");
    }

    #[test]
    fn rk_nfe_table_reports_correlations() {
        let stats: Vec<SolveStats> = [20, 40, 60, 80]
            .iter()
            .map(|n| SolveStats { nfe: *n, accepted: 4, rejected: 0, h_final: 0.1 })
            .collect();
        let mut t = RkNfeTable::new();
        t.push(0.0, &[1.0, 2.0, 3.0, 4.0], &stats); // perfectly correlated
        let j = t.to_json();
        let row = &j.as_arr().unwrap()[0];
        assert!((row.req("pearson").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert!((row.req("spearman").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        assert_eq!(row.req("mean_nfe").unwrap().as_f64(), Some(50.0));
        assert_eq!(t.table().row_count(), 1);
    }
}
