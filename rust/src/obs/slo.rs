//! Serving SLOs over deterministic time: per-tolerance-class deadline-miss
//! budgets with burn-rate windows measured in engine step ticks.
//!
//! Wall-clock SLOs don't replay; step-tick SLOs do.  Every retirement is
//! scored against its class's error budget (`miss_budget` = the tolerated
//! deadline-miss fraction) both cumulatively and inside tumbling windows
//! of `window_ticks` engine steps.  The **burn rate** of a window is its
//! miss rate divided by the budget — burn 1.0 spends the budget exactly,
//! burn 4.0 exhausts a four-window allowance in one window (the standard
//! fast-burn alerting framing, with logical steps standing in for hours).
//! Because ticks are deterministic, a burn-rate regression reproduces
//! bit-identically at any `TAYNODE_THREADS`, so the SLO table is CI-
//! diffable like every other report in this crate.
//!
//! ```
//! use taynode::obs::slo::SloTracker;
//! let mut slo = SloTracker::standard();
//! for tick in 0..100 {
//!     slo.record("realtime", tick, tick % 25 == 0); // 4% misses
//! }
//! let c = slo.class("realtime").unwrap();
//! assert_eq!((c.done, c.missed), (100, 4));
//! // 4% of a 5% budget: burning, but within budget.
//! let burn = slo.worst_burn("realtime").unwrap();
//! assert!(burn > 0.75 && burn < 1.0);
//! # assert!(slo.class("precise").unwrap().done == 0);
//! ```

use crate::util::bench::Table;
use crate::util::json::Json;

/// One class's SLO: tolerated deadline-miss fraction and the tumbling
/// burn-window width in engine step ticks.
#[derive(Clone, Debug, PartialEq)]
pub struct SloPolicy {
    pub class: &'static str,
    pub miss_budget: f64,
    pub window_ticks: u64,
}

/// The default budgets for the three serving tolerance classes: the
/// tighter the solver tolerance, the longer the deadline and the less
/// tolerated a miss.
pub const DEFAULT_POLICIES: [SloPolicy; 3] = [
    SloPolicy { class: "realtime", miss_budget: 0.05, window_ticks: 256 },
    SloPolicy { class: "standard", miss_budget: 0.01, window_ticks: 512 },
    SloPolicy { class: "precise", miss_budget: 0.001, window_ticks: 1024 },
];

/// One tumbling window's tallies.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloWindow {
    /// Window index (`done_tick / window_ticks`).
    pub idx: u64,
    pub done: u64,
    pub missed: u64,
}

impl SloWindow {
    pub fn miss_rate(&self) -> f64 {
        if self.done == 0 {
            0.0
        } else {
            self.missed as f64 / self.done as f64
        }
    }
}

/// One class's accumulated state.
#[derive(Clone, Debug, PartialEq)]
pub struct SloClass {
    pub policy: SloPolicy,
    pub done: u64,
    pub missed: u64,
    /// Tumbling windows with at least one retirement, ascending index.
    pub windows: Vec<SloWindow>,
}

impl SloClass {
    fn new(policy: SloPolicy) -> SloClass {
        SloClass { policy, done: 0, missed: 0, windows: Vec::new() }
    }

    pub fn miss_rate(&self) -> f64 {
        if self.done == 0 {
            0.0
        } else {
            self.missed as f64 / self.done as f64
        }
    }

    /// Miss rate ÷ budget: > 1.0 means this class is out of budget.
    pub fn burn(&self) -> f64 {
        self.miss_rate() / self.policy.miss_budget
    }

    /// The worst per-window burn rate (`None` before any retirement).
    pub fn worst_window_burn(&self) -> Option<f64> {
        self.windows
            .iter()
            .map(|w| w.miss_rate() / self.policy.miss_budget)
            .fold(None, |acc, b| Some(acc.map_or(b, |a: f64| a.max(b))))
    }
}

/// The per-class SLO tracker the serving engine feeds on every
/// retirement.  Deterministic by construction: state is a pure fold over
/// `(class, done_tick, miss)` triples, and the engine emits those in
/// retirement order, which is itself thread-count independent.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloTracker {
    pub classes: Vec<SloClass>,
}

impl SloTracker {
    /// A tracker over [`DEFAULT_POLICIES`].
    pub fn standard() -> SloTracker {
        SloTracker::with_policies(DEFAULT_POLICIES.to_vec())
    }

    pub fn with_policies(policies: Vec<SloPolicy>) -> SloTracker {
        SloTracker {
            classes: policies.into_iter().map(SloClass::new).collect(),
        }
    }

    pub fn class(&self, name: &str) -> Option<&SloClass> {
        self.classes.iter().find(|c| c.policy.class == name)
    }

    /// Score one retirement: `done_tick` is the engine step at which the
    /// request retired.  Unknown classes are ignored (a tracker only
    /// budgets the classes it was configured with).
    pub fn record(&mut self, class: &str, done_tick: u64, miss: bool) {
        let Some(c) = self.classes.iter_mut().find(|c| c.policy.class == class) else {
            return;
        };
        c.done += 1;
        c.missed += miss as u64;
        let idx = done_tick / c.policy.window_ticks.max(1);
        match c.windows.iter().position(|w| w.idx == idx) {
            Some(p) => {
                c.windows[p].done += 1;
                c.windows[p].missed += miss as u64;
            }
            None => {
                c.windows.push(SloWindow { idx, done: 1, missed: miss as u64 });
                c.windows.sort_by_key(|w| w.idx);
            }
        }
    }

    /// Worst per-window burn for `class` (`None` for an unknown class or
    /// one with no retirements yet).
    pub fn worst_burn(&self, class: &str) -> Option<f64> {
        self.class(class).and_then(SloClass::worst_window_burn)
    }

    /// Merge another tracker (same policies) — window tallies sum by
    /// index, so sharded drains fold to the same state as a serial one.
    pub fn absorb(&mut self, other: &SloTracker) {
        for oc in &other.classes {
            let Some(c) = self
                .classes
                .iter_mut()
                .find(|c| c.policy.class == oc.policy.class)
            else {
                continue;
            };
            c.done += oc.done;
            c.missed += oc.missed;
            for ow in &oc.windows {
                match c.windows.iter().position(|w| w.idx == ow.idx) {
                    Some(p) => {
                        c.windows[p].done += ow.done;
                        c.windows[p].missed += ow.missed;
                    }
                    None => {
                        c.windows.push(*ow);
                        c.windows.sort_by_key(|w| w.idx);
                    }
                }
            }
        }
    }

    /// The printable per-class table (all configured classes, even idle
    /// ones, so reports keep a fixed shape).
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "class", "done", "missed", "miss_rate", "budget", "burn", "worst_window", "windows",
        ]);
        for c in &self.classes {
            t.row(vec![
                c.policy.class.to_string(),
                c.done.to_string(),
                c.missed.to_string(),
                format!("{:.4}", c.miss_rate()),
                format!("{}", c.policy.miss_budget),
                format!("{:.3}", c.burn()),
                c.worst_window_burn()
                    .map_or("-".to_string(), |b| format!("{b:.3}")),
                c.windows.len().to_string(),
            ]);
        }
        t
    }

    /// Canonical JSON export, one object per configured class.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.classes
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("class", Json::str(c.policy.class)),
                        ("miss_budget", Json::num(c.policy.miss_budget)),
                        ("window_ticks", Json::num(c.policy.window_ticks as f64)),
                        ("done", Json::num(c.done as f64)),
                        ("missed", Json::num(c.missed as f64)),
                        ("miss_rate", Json::num(c.miss_rate())),
                        ("burn", Json::num(c.burn())),
                        (
                            "worst_window_burn",
                            c.worst_window_burn().map_or(Json::Null, Json::num),
                        ),
                        (
                            "windows",
                            Json::Arr(
                                c.windows
                                    .iter()
                                    .map(|w| {
                                        Json::arr_f64(&[
                                            w.idx as f64,
                                            w.done as f64,
                                            w.missed as f64,
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_and_windows_tally() {
        let mut slo = SloTracker::standard();
        // realtime: 10 requests in window 0, 2 miss; 10 in window 1, 0 miss.
        for i in 0..10 {
            slo.record("realtime", i, i < 2);
        }
        for i in 256..266 {
            slo.record("realtime", i, false);
        }
        slo.record("unknown-class", 0, true); // ignored
        let c = slo.class("realtime").unwrap();
        assert_eq!((c.done, c.missed), (20, 2));
        assert!((c.miss_rate() - 0.1).abs() < 1e-12);
        assert!((c.burn() - 2.0).abs() < 1e-12); // 10% of a 5% budget
        assert_eq!(c.windows.len(), 2);
        assert_eq!(c.windows[0], SloWindow { idx: 0, done: 10, missed: 2 });
        // Worst window burned 0.2/0.05 = 4×.
        assert!((slo.worst_burn("realtime").unwrap() - 4.0).abs() < 1e-12);
        assert_eq!(slo.worst_burn("precise"), None);
        assert_eq!(slo.worst_burn("no-such"), None);
    }

    #[test]
    fn absorb_equals_serial_fold() {
        let feed = |slo: &mut SloTracker, ticks: std::ops::Range<u64>| {
            for t in ticks {
                slo.record("standard", t, t % 7 == 0);
                slo.record("precise", t * 3, false);
            }
        };
        let mut serial = SloTracker::standard();
        feed(&mut serial, 0..600);
        let mut a = SloTracker::standard();
        feed(&mut a, 0..300);
        let mut b = SloTracker::standard();
        feed(&mut b, 300..600);
        a.absorb(&b);
        assert_eq!(a, serial);
    }

    #[test]
    fn report_shape_is_fixed_and_json_canonical() {
        let slo = SloTracker::standard();
        assert_eq!(slo.table().row_count(), 3); // idle classes still listed
        let j = slo.to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].str_of("class").unwrap(), "realtime");
        assert!(matches!(rows[0].req("worst_window_burn").unwrap(), Json::Null));
        assert_eq!(j.to_string(), slo.clone().to_json().to_string());
    }
}
