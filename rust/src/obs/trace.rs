//! Chrome Trace Event Format export as NDJSON.
//!
//! One JSON object per line (the JSON Lines flavor of the trace format —
//! Perfetto and `chrome://tracing` both accept a plain JSON array, so the
//! README documents wrapping the lines for viewers that want one; Perfetto
//! ingests the newline-delimited form directly).  Serialization goes
//! through [`util::json::Json`], whose `BTreeMap`-backed writer is
//! canonical — key order, number formatting — so byte-equality of two
//! trace files is a meaningful determinism check (`cmp` in CI, FNV hash in
//! the CLI).
//!
//! [`util::json::Json`]: crate::util::json::Json

use anyhow::{Context, Result};

use super::{Event, EventKind, Recorder};
use crate::util::json::Json;

/// Builder for a multi-process trace document: each instrumented unit
/// (a served model, a trainer, a pooled solve) becomes one Chrome `pid`
/// with a `process_name` metadata record, its events on `tid` = event
/// track, and its metrics registry attached as a `registry` metadata
/// record (viewers ignore unknown metadata; `repro trace` reads it back
/// for the counters table).
#[derive(Default)]
pub struct TraceDoc {
    lines: Vec<Json>,
}

fn arg_json(v: f64) -> Json {
    // The canonical writer degrades non-finite numbers to null; a diverged
    // solve can legitimately surface one (e.g. a final |h|), so encode
    // those as strings and keep the value visible in the viewer.
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(format!("{v}"))
    }
}

fn args_obj(args: &[(&str, f64)]) -> Json {
    Json::obj(
        args.iter()
            .filter(|(k, _)| !k.is_empty())
            .map(|(k, v)| (*k, arg_json(*v)))
            .collect(),
    )
}

fn event_json(pid: u64, e: &Event) -> Json {
    let mut fields = vec![
        ("name", Json::str(e.name)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(e.track as f64)),
        ("ts", Json::Num(e.ts as f64)),
        ("args", args_obj(&e.args)),
    ];
    match e.kind {
        EventKind::Span => {
            fields.push(("ph", Json::str("X")));
            fields.push(("dur", Json::Num(e.dur as f64)));
        }
        EventKind::Instant => {
            fields.push(("ph", Json::str("i")));
            fields.push(("s", Json::str("t")));
        }
        EventKind::Counter => {
            fields.push(("ph", Json::str("C")));
        }
    }
    Json::obj(fields)
}

impl TraceDoc {
    pub fn new() -> TraceDoc {
        TraceDoc::default()
    }

    /// Add one recorder's stream as Chrome process `pid` named `name`.
    /// A recorder that is off contributes only the name record.
    pub fn add_process(&mut self, pid: u64, name: &str, rec: &Recorder) {
        self.lines.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
        for e in rec.events() {
            self.lines.push(event_json(pid, e));
        }
        if let Some(reg) = rec.registry() {
            self.lines.push(Json::obj(vec![
                ("name", Json::str("registry")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                ("args", reg.to_json()),
            ]));
        }
    }

    pub fn line_count(&self) -> usize {
        self.lines.len()
    }

    /// The NDJSON document: one canonical JSON object per line, trailing
    /// newline included.
    pub fn to_ndjson(&self) -> String {
        let mut out = String::new();
        for l in &self.lines {
            out.push_str(&l.to_string());
            out.push('\n');
        }
        out
    }

    /// FNV-1a over the NDJSON bytes — the trace identity used by the CLI
    /// and the cross-thread-count CI check.
    pub fn hash(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_ndjson().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
}

/// Parse an NDJSON trace back into per-line values (round-trip tests, the
/// `perfdiff`-style tooling).  Blank lines are permitted; anything else
/// must be a complete JSON value or the whole parse fails with the
/// offending line number.
pub fn parse_ndjson(s: &str) -> Result<Vec<Json>> {
    let mut out = Vec::new();
    for (i, line) in s.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(Json::parse(line).with_context(|| format!("ndjson line {}", i + 1))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Counter, NO_ARGS};

    fn sample_doc() -> TraceDoc {
        let mut rec = Recorder::enabled();
        rec.span("traj", 3, 0, 17, [("nfe", 104.0), ("rejected", 2.0)]);
        rec.instant("admit_wave", 0, 5, [("rows", 4.0), ("", 0.0)]);
        rec.counter("queue_depth", 5, 2.0);
        rec.inc(Counter::Admitted, 4);
        let mut doc = TraceDoc::new();
        doc.add_process(0, "serve/toy", &rec);
        doc
    }

    #[test]
    fn ndjson_round_trips_through_the_parser() {
        let doc = sample_doc();
        let lines = parse_ndjson(&doc.to_ndjson()).unwrap();
        assert_eq!(lines.len(), doc.line_count());
        // Line 0: process_name metadata.
        assert_eq!(lines[0].str_of("name").unwrap(), "process_name");
        assert_eq!(lines[0].str_of("ph").unwrap(), "M");
        // Line 1: the span, with Chrome's complete-event phase.
        assert_eq!(lines[1].str_of("ph").unwrap(), "X");
        assert_eq!(lines[1].req("dur").unwrap().as_f64(), Some(17.0));
        assert_eq!(lines[1].req("tid").unwrap().as_f64(), Some(3.0));
        let args = lines[1].req("args").unwrap();
        assert_eq!(args.req("nfe").unwrap().as_f64(), Some(104.0));
        // Line 2: instant with scope, line 3: counter with value arg.
        assert_eq!(lines[2].str_of("s").unwrap(), "t");
        assert_eq!(
            lines[3].req("args").unwrap().req("value").unwrap().as_f64(),
            Some(2.0)
        );
        // Final line: the registry metadata record.
        let last = lines.last().unwrap();
        assert_eq!(last.str_of("name").unwrap(), "registry");
        let counters = last.req("args").unwrap().req("counters").unwrap();
        assert_eq!(counters.req("admitted").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn serialization_is_deterministic() {
        assert_eq!(sample_doc().to_ndjson(), sample_doc().to_ndjson());
        assert_eq!(sample_doc().hash(), sample_doc().hash());
    }

    #[test]
    fn non_finite_args_become_strings_not_panics() {
        let mut rec = Recorder::enabled();
        rec.span("traj", 0, 0, 1, [("h", f64::INFINITY), ("", 0.0)]);
        let mut doc = TraceDoc::new();
        doc.add_process(0, "p", &rec);
        let lines = parse_ndjson(&doc.to_ndjson()).unwrap();
        assert_eq!(lines[1].req("args").unwrap().str_of("h").unwrap(), "inf");
    }

    #[test]
    fn adversarial_ndjson_is_rejected_with_line_numbers() {
        for bad in [
            "{\"ph\":\"X\"}\n{truncated",
            "{\"a\":1}\n[1,2,\n",
            "{\"a\": NaN}\n",
            "{\"a\":1} trailing\n",
        ] {
            let err = parse_ndjson(bad).unwrap_err();
            assert!(format!("{err:#}").contains("ndjson line"), "{bad:?}");
        }
        // Blank interior lines are tolerated.
        assert_eq!(parse_ndjson("{\"a\":1}\n\n{\"b\":2}\n").unwrap().len(), 2);
    }

    #[test]
    fn off_recorder_exports_name_record_only() {
        let mut doc = TraceDoc::new();
        doc.add_process(1, "idle", &Recorder::off());
        assert_eq!(doc.line_count(), 1);
    }
}
