//! Determinism lint driver: walk the repo, run the rule catalog, print
//! `path:line: RULE message` diagnostics, exit nonzero on any hit.
//!
//! Usage: `taylint [--rules] [root]` (root defaults to the current
//! directory; `make lint` runs it from the repo root).

use std::path::PathBuf;
use std::process::ExitCode;

use taynode::analysis::{collect_sources, lint_sources, rules};

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--rules" => {
                for r in rules::RULES {
                    println!("{}  {}\n    {}", r.id, r.title, r.detail);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => {
                println!(
                    "taylint — determinism lint for the taynode repo\n\n\
                     usage: taylint [--rules] [root]\n\n\
                     Walks rust/src, rust/tests, benches/, examples/ under <root>\n\
                     (default: .) and enforces the invariant catalog (see --rules).\n\
                     Suppress a line with: // taylint: allow(<rule>) -- <reason>\n\
                     Exits 0 when clean, 1 when any diagnostic survives."
                );
                return ExitCode::SUCCESS;
            }
            other => root = PathBuf::from(other),
        }
    }
    let files = match collect_sources(&root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("taylint: cannot read sources under {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if files.is_empty() {
        eprintln!("taylint: no .rs sources found under {}", root.display());
        return ExitCode::FAILURE;
    }
    let diags = lint_sources(&files);
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        println!("taylint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        println!("taylint: {} diagnostic(s)", diags.len());
        ExitCode::FAILURE
    }
}
