"""Differentiable fixed-grid Runge-Kutta integrators (build-time, JAX).

These are the discretize-then-optimize solvers used *inside* exported train
steps (the paper's fixed-step training rows in Tables 2-4).  The adaptive
solvers that measure NFE at evaluation time live in Rust
(``rust/src/solvers``) and call the exported dynamics executables.

States are pytrees so augmented systems (state, regularizer accumulators,
log-determinants, ...) integrate with the same code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Explicit Butcher tableaux: (a_lower_rows, b, c).
TABLEAUX = {
    "euler": ((), (1.0,), (0.0,)),
    "midpoint": (((0.5,),), (0.0, 1.0), (0.0, 0.5)),
    "heun2": (((1.0,),), (0.5, 0.5), (0.0, 1.0)),
    "bosh3": (
        ((0.5,), (0.0, 0.75)),
        (2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0),
        (0.0, 0.5, 0.75),
    ),
    "rk4": (
        ((0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
        (1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0),
        (0.0, 0.5, 0.5, 1.0),
    ),
}


def _tree_axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda a, b: b + alpha * a, x, y)


def _tree_scale_sum(coeffs, trees):
    out = None
    for c, tr in zip(coeffs, trees):
        if c == 0.0:
            continue
        scaled = jax.tree_util.tree_map(lambda a: c * a, tr)
        out = scaled if out is None else jax.tree_util.tree_map(jnp.add, out, scaled)
    return out


def rk_step(f, y, t, dt, method: str = "rk4"):
    """One explicit RK step of the given tableau.  ``f(y, t) -> dy``."""
    a, b, c = TABLEAUX[method]
    ks = [f(y, t)]
    for i, row in enumerate(a):
        yi = y
        for j, aij in enumerate(row):
            if aij != 0.0:
                yi = _tree_axpy(aij * dt, ks[j], yi)
        ks.append(f(yi, t + c[i + 1] * dt))
    incr = _tree_scale_sum(b, ks)
    return _tree_axpy(dt, incr, y)


def odeint_grid(f, y0, t0: float, t1: float, steps: int, method: str = "rk4"):
    """Integrate ``dy/dt = f(y, t)`` on a uniform grid of ``steps`` steps.

    Returns the final state.  Differentiable (unrolled by ``lax.scan``).
    """
    dt = (t1 - t0) / steps

    def body(y, i):
        t = t0 + i.astype(jnp.float32) * dt
        return rk_step(f, y, t, dt, method), None

    yT, _ = jax.lax.scan(body, y0, jnp.arange(steps))
    return yT


def odeint_grid_traj(f, y0, t0: float, t1: float, steps: int, method: str = "rk4"):
    """Like :func:`odeint_grid` but also returns the state after every step
    (used by the latent-ODE decoder, which needs the whole trajectory)."""
    dt = (t1 - t0) / steps

    def body(y, i):
        t = t0 + i.astype(jnp.float32) * dt
        ynext = rk_step(f, y, t, dt, method)
        return ynext, ynext

    yT, traj = jax.lax.scan(body, y0, jnp.arange(steps))
    return yT, traj
