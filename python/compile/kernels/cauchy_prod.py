"""Pallas kernel: truncated Cauchy product (jet's inner loop).

Taylor-mode multiplication of two K-truncated series costs O(K^2)
multiply-adds per element (paper §4).  The coefficient stacks are laid out
[K+1, N] with the feature axis N on the VPU lane dimension and the (tiny,
K <= 7) coefficient axis unrolled at trace time — the triangular convolution
becomes K(K+1)/2 vectorized FMAs over a [K+1, block_n] VMEM block.

A GPU port would assign one thread per output element; on TPU the lane axis
gives us the element parallelism for free and the unrolled k-loop keeps
everything in registers/VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(K1: int):
    def kernel(z_ref, w_ref, o_ref):
        z = z_ref[...]
        w = w_ref[...]
        for k in range(K1):
            acc = z[0] * w[k]
            for j in range(1, k + 1):
                acc = acc + z[j] * w[k - j]
            o_ref[k, :] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n",))
def cauchy_prod(z, w, block_n: int = 128):
    """out[k] = sum_{j<=k} z[j] * w[k-j]; z, w: [K+1, N]."""
    K1, N = z.shape
    if N % block_n != 0:
        block_n = N
    grid = (N // block_n,)
    return pl.pallas_call(
        _make_kernel(K1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K1, block_n), lambda i: (0, i)),
            pl.BlockSpec((K1, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((K1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((K1, N), z.dtype),
        interpret=True,
    )(z, w)
