"""Pallas kernel: fused dynamics-MLP forward (the solve-time hot spot).

One adaptive-solver NFE = one evaluation of this MLP over the whole batch.
On the authors' GPUs this was two cuBLAS GEMMs with elementwise kernels in
between (four HBM round-trips for the activations).  The TPU-style mapping
(DESIGN.md §Hardware-Adaptation):

  * grid over batch tiles of ``block_b`` rows; the x-tile lives in VMEM,
  * both (small) weight matrices are broadcast VMEM-resident across the grid
    (index_map pins them to block (0, 0)),
  * concat-time -> GEMM -> tanh -> GEMM -> bias are fused in one kernel, so
    the [B, H] activation never visits HBM,
  * the GEMMs target the MXU (f32 here; bf16 on real hardware).

VMEM per grid step = block_b*(D + H + D) + (D+1)*H + (H+1)*D + H + D floats;
for D=196, H=100, block_b=32 that is ~56 KiB — far under the ~16 MiB VMEM
budget, so block_b can grow until the MXU is saturated (see EXPERIMENTS.md
§Perf for the sweep).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpret lowering emits plain HLO, which is what the Rust
runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, t_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0]
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    z1 = jnp.tanh(x)
    # [z1 ; t] @ W1 == z1 @ W1[:-1] + t * W1[-1]
    h1 = z1 @ w1[:-1] + t * w1[-1] + b1_ref[...]
    z2 = jnp.tanh(h1)
    o_ref[...] = z2 @ w2[:-1] + t * w2[-1] + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def fused_mlp(x, t, w1, b1, w2, b2, block_b: int = 32):
    """Fused dynamics MLP; semantics of :func:`ref.fused_mlp_ref`.

    x: [B, D] with B divisible by ``block_b`` (callers pad if needed).
    """
    B, D = x.shape
    H = b1.shape[0]
    if B % block_b != 0:
        block_b = B  # degenerate fallback: single tile
    t_arr = jnp.broadcast_to(jnp.asarray(t, dtype=x.dtype), (1,))
    grid = (B // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, D), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((D + 1, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H + 1, D), lambda i: (0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=True,
    )(x, t_arr, w1, b1, w2, b2)
