from .fused_mlp import fused_mlp
from .cauchy_prod import cauchy_prod
from . import ref

__all__ = ["fused_mlp", "cauchy_prod", "ref"]
