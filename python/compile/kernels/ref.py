"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``) and also serve as the
default implementation inside differentiated train steps (XLA fuses them
well; the Pallas path is used on the inference/NFE hot path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_mlp_ref(x, t, w1, b1, w2, b2):
    """The paper's dynamics MLP (Appendix B.2), batched.

        z1 = tanh(x)
        h1 = W1 [z1 ; t] + b1
        z2 = tanh(h1)
        y  = W2 [z2 ; t] + b2

    x: [B, D], t: scalar, w1: [D+1, H], b1: [H], w2: [H+1, D], b2: [D].
    Returns [B, D].
    """
    z1 = jnp.tanh(x)
    h1 = z1 @ w1[:-1] + t * w1[-1] + b1
    z2 = jnp.tanh(h1)
    return z2 @ w2[:-1] + t * w2[-1] + b2


def cauchy_prod_ref(z, w):
    """Truncated Cauchy product over stacked Taylor coefficients.

    z, w: [K+1, N] stacks of normalized coefficients.
    out[k] = sum_{j=0..k} z[j] * w[k-j]   (shape [K+1, N])
    """
    K1 = z.shape[0]
    rows = []
    for k in range(K1):
        acc = z[0] * w[k]
        for j in range(1, k + 1):
            acc = acc + z[j] * w[k - j]
        rows.append(acc)
    return jnp.stack(rows, axis=0)
