"""Artifact catalog: every HLO executable the Rust runtime consumes.

Each entry declares the python function, its input specs (with *roles* so
the Rust coordinator knows which inputs are parameters, optimizer state,
batch data, probes or scalars) and metadata.  ``aot.py`` lowers the catalog
to ``artifacts/``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .models import toy, mnist, latent_ode, cnf

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Artifact:
    def __init__(self, name, fn, inputs, model, kind, meta=None):
        self.name = name
        self.fn = fn
        self.inputs = inputs  # [(role, name, ShapeDtypeStruct)]
        self.model = model
        self.kind = kind
        self.meta = meta or {}


def _param_inputs(ps, prefix="param"):
    return [(f"{prefix}:{n}", n, spec(s)) for n, s in ps.entries]


def _opt_inputs(ps, slot):
    return [(f"opt:{slot}:{n}", f"{slot}_{n}", spec(s)) for n, s in ps.entries]


def catalog() -> list[Artifact]:
    arts: list[Artifact] = []

    # ----- toy (Figs 1, 9) --------------------------------------------------
    tps = toy.param_spec()
    B = toy.BATCH
    for tag, order in [("unreg", 0), ("k2", 2), ("k3", 3), ("k6", 6)]:
        arts.append(Artifact(
            f"toy_train_{tag}_s16",
            toy.make_train_step(reg_order=order, steps=16),
            _param_inputs(tps) + _opt_inputs(tps, "m")
            + [("batch:x", "x", spec((B, toy.D))),
               ("scalar:lam", "lam", spec(())),
               ("scalar:lr", "lr", spec(()))],
            "toy", "train", {"reg": tag, "steps": 16}))
    for nb, suffix in [(B, ""), (1, "_b1")]:
        arts.append(Artifact(
            f"toy_dynamics{suffix}", toy.dynamics,
            _param_inputs(tps)
            + [("batch:z", "z", spec((nb, toy.D))),
               ("scalar:t", "t", spec(()))],
            "toy", "dynamics", {"batch": nb}))

    # ----- mnist (Figs 3, 5-8, 10, 11; Table 3) ------------------------------
    mps = mnist.param_spec()
    B, D = mnist.BATCH, mnist.D
    mnist_variants = [
        ("unreg", "none", 0, 2), ("unreg", "none", 0, 8),
        ("rnode", "rnode", 0, 2), ("rnode", "rnode", 0, 8),
        ("k1", "taynode", 1, 8),
        ("k2", "taynode", 2, 2), ("k2", "taynode", 2, 8),
        ("k3", "taynode", 3, 2), ("k3", "taynode", 3, 8),
        ("k4", "taynode", 4, 8),
    ]
    for tag, reg, order, steps in mnist_variants:
        arts.append(Artifact(
            f"mnist_train_{tag}_s{steps}",
            mnist.make_train_step(reg=reg, reg_order=order, steps=steps),
            _param_inputs(mps) + _opt_inputs(mps, "m")
            + [("batch:x", "x", spec((B, D))),
               ("batch:labels", "labels", spec((B,), I32)),
               ("rng:eps", "eps", spec((B, D))),
               ("scalar:lam", "lam", spec(())),
               ("scalar:lr", "lr", spec(()))],
            "mnist", "train", {"reg": tag, "steps": steps, "order": order}))
    dyn_params = [(r, n, s) for r, n, s in _param_inputs(mps)
                  if n in ("w1", "b1", "w2", "b2")]
    for nb, suffix in [(B, ""), (1, "_b1")]:
        arts.append(Artifact(
            f"mnist_dynamics{suffix}", mnist.dynamics,
            dyn_params + [("batch:z", "z", spec((nb, D))),
                          ("scalar:t", "t", spec(()))],
            "mnist", "dynamics", {"batch": nb}))
    arts.append(Artifact(
        "mnist_dynamics_pallas", mnist.dynamics_pallas,
        dyn_params + [("batch:z", "z", spec((B, D))),
                      ("scalar:t", "t", spec(()))],
        "mnist", "dynamics", {"batch": B, "pallas": True}))
    arts.append(Artifact(
        "mnist_aug_dynamics", mnist.aug_dynamics,
        dyn_params + [("batch:state", "state", spec((B, D + 6))),
                      ("scalar:t", "t", spec(())),
                      ("rng:eps", "eps", spec((B, D)))],
        "mnist", "aug_dynamics", {"batch": B, "aug_cols": 6}))
    head_params = [(r, n, s) for r, n, s in _param_inputs(mps)
                   if n in ("wh", "bh")]
    arts.append(Artifact(
        "mnist_head", mnist.head_metrics,
        head_params + [("batch:z1", "z1", spec((B, D))),
                       ("batch:labels", "labels", spec((B,), I32))],
        "mnist", "metrics", {}))

    # ----- latent ODE (Fig 4, Fig 12) ----------------------------------------
    lps = latent_ode.param_spec()
    B, Tn, Fn, L = latent_ode.BATCH, latent_ode.T, latent_ode.F, latent_ode.L
    for tag, reg, order in [("unreg", "none", 0), ("k2", "taynode", 2),
                            ("k3", "taynode", 3)]:
        arts.append(Artifact(
            f"latent_train_{tag}",
            latent_ode.make_train_step(reg=reg, reg_order=order),
            _param_inputs(lps) + _opt_inputs(lps, "m") + _opt_inputs(lps, "v")
            + [("batch:x", "x", spec((B, Tn, Fn))),
               ("batch:mask", "mask", spec((B, Tn, Fn))),
               ("rng:eps_z", "eps_z", spec((B, L))),
               ("scalar:lam", "lam", spec(())),
               ("scalar:lr", "lr", spec(())),
               ("scalar:step", "step", spec(()))],
            "latent", "train", {"reg": tag, "order": order}))
    arts.append(Artifact(
        "latent_encode", latent_ode.encode,
        _param_inputs(lps)
        + [("batch:x", "x", spec((B, Tn, Fn))),
           ("batch:mask", "mask", spec((B, Tn, Fn)))],
        "latent", "encode", {}))
    ldyn = [(r, n, s) for r, n, s in _param_inputs(lps)
            if n in ("w1", "b1", "w2", "b2")]
    arts.append(Artifact(
        "latent_dynamics", latent_ode.dynamics,
        ldyn + [("batch:z", "z", spec((B, L))), ("scalar:t", "t", spec(()))],
        "latent", "dynamics", {"batch": B}))
    ldec = [(r, n, s) for r, n, s in _param_inputs(lps)
            if n in ("wd1", "bd1", "wd2", "bd2")]
    arts.append(Artifact(
        "latent_traj_metrics", latent_ode.traj_metrics,
        ldec + [("batch:ztraj", "ztraj", spec((Tn, B, L))),
                ("batch:x", "x", spec((B, Tn, Fn))),
                ("batch:mask", "mask", spec((B, Tn, Fn)))],
        "latent", "metrics", {}))

    # ----- CNF / FFJORD (Tables 2, 4; Fig 5) ---------------------------------
    for cfg, steps_list in [("tab", (4, 8, 16)), ("img", (5, 8))]:
        cps = cnf.param_spec(cfg)
        d = cnf.CONFIGS[cfg]["d"]
        B = cnf.CONFIGS[cfg]["batch"]
        variants = [("unreg", "none", 0), ("rnode", "rnode", 0),
                    ("k2", "taynode", 2)]
        if cfg == "tab":
            variants.append(("k3", "taynode", 3))
        for tag, reg, order in variants:
            for steps in steps_list:
                if tag == "k3" and steps != 8:
                    continue
                arts.append(Artifact(
                    f"cnf_{cfg}_train_{tag}_s{steps}",
                    cnf.make_train_step(cfg, reg=reg, reg_order=order,
                                        steps=steps),
                    _param_inputs(cps) + _opt_inputs(cps, "m")
                    + _opt_inputs(cps, "v")
                    + [("batch:x", "x", spec((B, d))),
                       ("rng:eps", "eps", spec((B, d))),
                       ("scalar:lam", "lam", spec(())),
                       ("scalar:lr", "lr", spec(())),
                       ("scalar:step", "step", spec(()))],
                    f"cnf_{cfg}", "train",
                    {"reg": tag, "steps": steps, "order": order}))
        arts.append(Artifact(
            f"cnf_{cfg}_aug_dynamics", cnf.aug_dynamics,
            _param_inputs(cps)
            + [("batch:state", "state", spec((B, d + 4))),
               ("scalar:t", "t", spec(())),
               ("rng:eps", "eps", spec((B, d)))],
            f"cnf_{cfg}", "aug_dynamics", {"batch": B, "aug_cols": 4}))
        arts.append(Artifact(
            f"cnf_{cfg}_nll", cnf.nll_metrics,
            [("batch:z1", "z1", spec((B, d))),
             ("batch:logdet", "logdet", spec((B,)))],
            f"cnf_{cfg}", "metrics", {}))

    return arts


MODEL_SPECS = {
    "toy": (toy.param_spec(), toy.init,
            {"d": toy.D, "h": toy.H, "batch": toy.BATCH}),
    "mnist": (mnist.param_spec(), mnist.init, mnist.HYPER),
    "latent": (latent_ode.param_spec(), latent_ode.init, latent_ode.HYPER),
    "cnf_tab": (cnf.param_spec("tab"), lambda s=0: cnf.init("tab", s),
                cnf.CONFIGS["tab"]),
    "cnf_img": (cnf.param_spec("img"), lambda s=0: cnf.init("img", s),
                cnf.CONFIGS["img"]),
}
