"""Taylor-mode automatic differentiation, implemented from scratch.

This module is the paper's §4 / Appendix A: propagation of truncated Taylor
polynomials through programs ("jet"), and the recursive computation of the
Taylor coefficients of an ODE *solution* trajectory (Algorithm 1), which is
what the TayNODE regularizer `R_K` needs.

Conventions
-----------
Internally a :class:`TSeries` stores *normalized Taylor coefficients*
``x_[i] = x_i / i!`` where ``x_i = d^i x / dt^i`` (Appendix A.1).  The public
:func:`jet` API follows the convention of ``jax.experimental.jet``: callers
pass and receive *derivative coefficients* ``x_i`` (so our implementation can
be cross-checked against JAX's in the test-suite).

Cost: propagating a K-truncated series through a program costs O(K^2) per
multiplication (a truncated Cauchy product) instead of the O(exp K) of
naively nesting first-order JVPs — see ``python/tests/test_jet_scaling.py``
for the measured asymptotics, and ``kernels/cauchy_prod.py`` for the Pallas
kernel implementing the Cauchy product.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "TSeries",
    "jet",
    "ode_jet",
    "ode_total_derivative",
    "rk_reg_integrand",
    "nested_jvp_coeffs",
]


def _fact(k: int) -> float:
    return float(math.factorial(k))


class TSeries:
    """A truncated Taylor polynomial ``x(t) = sum_i c[i] * t^i`` (normalized
    coefficients).  Coefficients are jnp arrays of identical shape (or
    broadcastable scalars)."""

    __slots__ = ("c",)

    def __init__(self, coeffs):
        self.c = list(coeffs)
        if not self.c:
            raise ValueError("TSeries needs at least the 0th coefficient")

    # -- constructors -------------------------------------------------------
    @staticmethod
    def constant(value, order: int) -> "TSeries":
        z = jnp.zeros_like(value)
        return TSeries([value] + [z] * order)

    @staticmethod
    def time(t0, order: int) -> "TSeries":
        """The series of the independent variable itself: t0 + 1*t."""
        one = jnp.ones_like(t0)
        zero = jnp.zeros_like(t0)
        coeffs = [t0]
        if order >= 1:
            coeffs.append(one)
        coeffs.extend([zero] * (order - 1))
        return TSeries(coeffs)

    # -- inspection ---------------------------------------------------------
    @property
    def order(self) -> int:
        return len(self.c) - 1

    @property
    def primal(self):
        return self.c[0]

    def derivative_coeff(self, k: int):
        """Unnormalized derivative coefficient ``d^k x/dt^k = k! * c[k]``."""
        return self.c[k] * _fact(k)

    # -- ring operations ----------------------------------------------------
    def __add__(self, other):
        if isinstance(other, TSeries):
            _check(self, other)
            return TSeries([a + b for a, b in zip(self.c, other.c)])
        return TSeries([self.c[0] + other] + self.c[1:])

    __radd__ = __add__

    def __neg__(self):
        return TSeries([-a for a in self.c])

    def __sub__(self, other):
        if isinstance(other, TSeries):
            _check(self, other)
            return TSeries([a - b for a, b in zip(self.c, other.c)])
        return TSeries([self.c[0] - other] + self.c[1:])

    def __rsub__(self, other):
        return (-self).__add__(other)

    def __mul__(self, other):
        if isinstance(other, TSeries):
            _check(self, other)
            K = self.order
            out = []
            for k in range(K + 1):
                acc = self.c[0] * other.c[k]
                for j in range(1, k + 1):
                    acc = acc + self.c[j] * other.c[k - j]
                out.append(acc)
            return TSeries(out)
        return TSeries([a * other for a in self.c])

    __rmul__ = __mul__

    def __truediv__(self, other):
        if isinstance(other, TSeries):
            _check(self, other)
            # y = z / w  =>  y_[k] = (z_[k] - sum_{j<k} y_[j] w_[k-j]) / w_[0]
            K = self.order
            out = []
            for k in range(K + 1):
                acc = self.c[k]
                for j in range(k):
                    acc = acc - out[j] * other.c[k - j]
                out.append(acc / other.c[0])
            return TSeries(out)
        return TSeries([a / other for a in self.c])

    def __rtruediv__(self, other):
        return TSeries.constant(jnp.asarray(other) * jnp.ones_like(self.c[0]),
                                self.order).__truediv__(self)

    def __pow__(self, n: int):
        if not isinstance(n, int) or n < 0:
            raise ValueError("TSeries.__pow__ supports non-negative ints")
        if n == 0:
            return TSeries.constant(jnp.ones_like(self.c[0]), self.order)
        out = self
        for _ in range(n - 1):
            out = out * self
        return out


def _check(a: TSeries, b: TSeries) -> None:
    if a.order != b.order:
        raise ValueError(f"order mismatch: {a.order} vs {b.order}")


# ---------------------------------------------------------------------------
# Nonlinear propagation rules (Table 1 / Griewank & Walther ch. 13).
# Each rule computes output coefficients from input coefficients using the
# ODE the elementary function satisfies:  if  s = g(z)  with  s' = u(s) z'
# then  k*s_[k] = sum_{j=1..k} (j * z_[j]) * u_[k-j].
# ---------------------------------------------------------------------------

def t_exp(z: TSeries) -> TSeries:
    y = [jnp.exp(z.c[0])]
    for k in range(1, z.order + 1):
        acc = None
        for j in range(1, k + 1):
            term = (j * z.c[j]) * y[k - j]
            acc = term if acc is None else acc + term
        y.append(acc / k)
    return TSeries(y)


def t_log(z: TSeries) -> TSeries:
    # z y' = z'  =>  k z_[0] y_[k] = k z_[k] - sum_{j=1..k-1} (k-j) y_[k-j] z_[j]
    y = [jnp.log(z.c[0])]
    for k in range(1, z.order + 1):
        acc = k * z.c[k]
        for j in range(1, k):
            acc = acc - (k - j) * y[k - j] * z.c[j]
        y.append(acc / (k * z.c[0]))
    return TSeries(y)


def t_sqrt(z: TSeries) -> TSeries:
    # y*y = z  =>  y_[k] = (z_[k] - sum_{1<=j<=k-1} y_[j] y_[k-j]) / (2 y_[0])
    y = [jnp.sqrt(z.c[0])]
    for k in range(1, z.order + 1):
        acc = z.c[k]
        for j in range(1, k):
            acc = acc - y[j] * y[k - j]
        y.append(acc / (2.0 * y[0]))
    return TSeries(y)


def t_sin_cos(z: TSeries):
    s = [jnp.sin(z.c[0])]
    c = [jnp.cos(z.c[0])]
    for k in range(1, z.order + 1):
        sa = None
        ca = None
        for j in range(1, k + 1):
            zj = j * z.c[j]
            ts = zj * c[k - j]
            tc = zj * s[k - j]
            sa = ts if sa is None else sa + ts
            ca = tc if ca is None else ca + tc
        s.append(sa / k)
        c.append(-ca / k)
    return TSeries(s), TSeries(c)


def t_sin(z: TSeries) -> TSeries:
    return t_sin_cos(z)[0]


def t_cos(z: TSeries) -> TSeries:
    return t_sin_cos(z)[1]


def _ode_rule(z: TSeries, g0, u_of_s):
    """Generic rule for s = g(z) with s' = u(s) * z'.

    ``g0`` is g evaluated at the primal; ``u_of_s(s_coeffs, m)`` returns the
    m-th coefficient of u(s) given the s coefficients computed so far
    (indices 0..m are available when requested, m < current k).
    """
    s = [g0]
    for k in range(1, z.order + 1):
        acc = None
        for j in range(1, k + 1):
            term = (j * z.c[j]) * u_of_s(s, k - j)
            acc = term if acc is None else acc + term
        s.append(acc / k)
    return TSeries(s)


def t_tanh(z: TSeries) -> TSeries:
    # s' = (1 - s^2) z'
    u_cache: dict[int, jnp.ndarray] = {}

    def u(s, m):
        if m not in u_cache:
            acc = s[0] * s[m]
            for i in range(1, m + 1):
                acc = acc + s[i] * s[m - i]
            one = 1.0 if m == 0 else 0.0
            u_cache[m] = one - acc
        return u_cache[m]

    # NOTE: u depends on s[m] which is available because m = k - j <= k - 1.
    # But the cache must be invalidated per-k?  No: s[0..m] never change once
    # appended, so caching is sound.
    return _ode_rule(z, jnp.tanh(z.c[0]), u)


def t_sigmoid(z: TSeries) -> TSeries:
    # s' = s (1 - s) z'
    u_cache: dict[int, jnp.ndarray] = {}

    def u(s, m):
        if m not in u_cache:
            acc = s[0] * s[m]
            for i in range(1, m + 1):
                acc = acc + s[i] * s[m - i]
            u_cache[m] = s[m] - acc
        return u_cache[m]

    return _ode_rule(z, jax.nn.sigmoid(z.c[0]), u)


def t_softplus(z: TSeries) -> TSeries:
    # y' = sigmoid(z) z'
    sig = t_sigmoid(z)
    y = [jax.nn.softplus(z.c[0])]
    for k in range(1, z.order + 1):
        acc = None
        for j in range(1, k + 1):
            term = (j * z.c[j]) * sig.c[k - j]
            acc = term if acc is None else acc + term
        y.append(acc / k)
    return TSeries(y)


# ---------------------------------------------------------------------------
# jet: the public Taylor-mode entry point (jax.experimental.jet convention)
# ---------------------------------------------------------------------------

def jet(f, primals, series):
    """Compute the truncated Taylor expansion of ``f`` along a path.

    Mirrors ``jax.experimental.jet.jet``: ``primals`` is a tuple of arrays
    ``x_0``, ``series`` a tuple of lists ``[x_1, ..., x_K]`` of *derivative*
    coefficients.  Returns ``(y_0, [y_1, ..., y_K])``.

    ``f`` must be written against the :mod:`compile.tmath` generic ops so it
    can consume :class:`TSeries` arguments.
    """
    K = len(series[0])
    ins = []
    for p, s in zip(primals, series):
        coeffs = [p] + [si / _fact(i + 1) for i, si in enumerate(s)]
        ins.append(TSeries(coeffs))
    out = f(*ins)
    single = not isinstance(out, (tuple, list))
    outs = (out,) if single else tuple(out)
    prim_out = []
    ser_out = []
    for o in outs:
        if not isinstance(o, TSeries):
            o = TSeries.constant(o, K)
        prim_out.append(o.c[0])
        ser_out.append([o.derivative_coeff(k) for k in range(1, K + 1)])
    if single:
        return prim_out[0], ser_out[0]
    return tuple(prim_out), tuple(ser_out)


# ---------------------------------------------------------------------------
# Algorithm 1: Taylor coefficients of the ODE solution by recursive jet
# ---------------------------------------------------------------------------

def ode_jet(f, z0, t0, order: int):
    """Derivative coefficients ``[x_1, ..., x_order]`` of the solution of
    ``dz/dt = f(z, t)`` through ``(z0, t0)``.

    ``f(z, t)`` must be tmath-generic.  Recursion (paper Algorithm 1, in
    derivative-coefficient form): ``x_{k+1} = y_k`` where ``y`` is the jet of
    ``f`` along the partially-built solution path.  Time is handled by
    augmenting with the trivial series ``t0 + t`` (Appendix A.2.1).
    """
    t0 = jnp.asarray(t0, dtype=z0.dtype)
    x = [f(z0, t0)]  # x_1 = dz/dt
    for k in range(1, order):
        # Build the k-truncated solution path and push it through f.
        zs = TSeries([z0] + [x[i] / _fact(i + 1) for i in range(k)])
        ts = TSeries.time(t0, k)
        y = f(zs, ts)
        # y_[k] is the k-th *Taylor* coefficient of f(z(t), t); the next
        # derivative coefficient of the solution is x_{k+1} = k! * y_[k] ...
        # with x_{k+1}/(k+1)! = y_[k]/(k+1) <=> x_{k+1} = (k+1)! * y_[k] / (k+1)? No:
        # dz/dt = y(t)  =>  (k+1) z_[k+1] = y_[k]  =>  x_{k+1} = k! * y_[k].
        x.append(y.c[k] * _fact(k))
    return x


def ode_total_derivative(f, z0, t0, order: int):
    """``d^order z / dt^order`` of the solution trajectory at (z0, t0)."""
    return ode_jet(f, z0, t0, order)[order - 1]


def rk_reg_integrand(f, z, t, order: int):
    """The TayNODE regularizer integrand (eq. 1), dimension-normalized as in
    Appendix B: ``||d^K z/dt^K||^2 / D`` per batch element.

    ``z`` has shape [B, D] (or [D]); returns shape [B] (or scalar).
    """
    dK = ode_total_derivative(f, z, t, order)
    sq = dK * dK
    return jnp.sum(sq, axis=-1) / sq.shape[-1]


# ---------------------------------------------------------------------------
# Naive nested-JVP baseline (O(exp K)) — kept for the §Perf comparison.
# ---------------------------------------------------------------------------

def nested_jvp_coeffs(f, z0, t0, order: int):
    """Derivative coefficients of the ODE solution via recursively nested
    first-order JVPs.  Exponential in ``order``; used only to demonstrate the
    asymptotic advantage of Taylor mode (paper §4)."""
    t0 = jnp.asarray(t0, dtype=z0.dtype)

    def g(state):
        z, t = state
        return (f(z, t), jnp.ones_like(t))

    # d^{k+1} z/dt^{k+1} = (d^k/dt^k) f(z(t), t); build the tower recursively.
    def nth(state, k):
        if k == 0:
            return g(state)
        fn = lambda s: nth(s, k - 1)
        _, dot = jax.jvp(fn, (state,), (g(state),))
        return dot

    out = []
    state = (z0, t0)
    for k in range(order):
        out.append(nth(state, k)[0])
    return out
