"""AOT compilation: lower the artifact catalog to HLO text + manifest.

Run once at build time (``make artifacts``).  Python never runs again after
this; the Rust coordinator loads the HLO text through the PJRT C API.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import catalog, MODEL_SPECS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_artifact(art, out_dir: str) -> dict:
    specs = [s for _, _, s in art.inputs]
    t0 = time.time()
    # keep_unused: the manifest promises every declared input is a real HLO
    # parameter (otherwise XLA prunes e.g. the eps probe of unregularized
    # variants and the Rust-side input count mismatches).
    lowered = jax.jit(art.fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"{art.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(art.fn, *specs)
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    entry = {
        "file": fname,
        "model": art.model,
        "kind": art.kind,
        "meta": art.meta,
        "inputs": [
            {"role": role, "name": name, "shape": list(s.shape),
             "dtype": str(s.dtype)}
            for role, name, s in art.inputs
        ],
        "outputs": [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in out_shapes
        ],
    }
    dt = time.time() - t0
    print(f"  [{dt:6.2f}s] {art.name}  ({len(text)//1024} KiB)")
    return entry


def export_params(out_dir: str) -> dict:
    models = {}
    for mname, (pspec, init_fn, hyper) in MODEL_SPECS.items():
        params = init_fn(0)
        flat = pspec.flatten(params)
        fname = f"{mname}_params.bin"
        flat.astype("<f4").tofile(os.path.join(out_dir, fname))
        models[mname] = {
            "hyper": hyper,
            "params": {"file": fname, "layout": pspec.layout(),
                       "total": int(flat.size)},
        }
        print(f"  params {mname}: {flat.size} floats -> {fname}")
    return models


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names (dev aid)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print("exporting parameters ...")
    models = export_params(args.out)

    print("lowering artifacts ...")
    executables = {}
    for art in catalog():
        if args.only and args.only not in art.name:
            continue
        executables[art.name] = export_artifact(art, args.out)

    manifest = {"version": 1, "models": models, "executables": executables}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(executables)} executables")


if __name__ == "__main__":
    main()
