"""Generic math ops that dispatch on plain jnp arrays OR :class:`TSeries`.

Model dynamics (the functions fed to ODE solvers and to the Taylor-mode
regularizer) are written exclusively against this namespace, so a single
definition serves three consumers:

  1. plain evaluation inside exported HLO (arguments are jnp arrays),
  2. jet propagation for the `R_K` regularizer (arguments are TSeries),
  3. the pure-jnp reference oracles for the Pallas kernels.

Linear operations apply coefficient-wise to a series; nonlinear ones use the
recurrence rules in :mod:`compile.taylor`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import taylor as T

TSeries = T.TSeries


def _is_series(x) -> bool:
    return isinstance(x, TSeries)


def _lift(x, like: TSeries) -> TSeries:
    if _is_series(x):
        return x
    return TSeries.constant(jnp.asarray(x) * jnp.ones_like(like.c[0]), like.order)


# -- linear ------------------------------------------------------------------

def add(a, b):
    if _is_series(a) or _is_series(b):
        ref = a if _is_series(a) else b
        return _lift(a, ref) + _lift(b, ref)
    return a + b


def sub(a, b):
    if _is_series(a) or _is_series(b):
        ref = a if _is_series(a) else b
        return _lift(a, ref) - _lift(b, ref)
    return a - b


def mul(a, b):
    if _is_series(a) and not _is_series(b):
        return a * b  # scalar/constant factor, coefficient-wise
    if _is_series(b) and not _is_series(a):
        return b * a
    if _is_series(a):
        return a * b
    return a * b


def div(a, b):
    if _is_series(a) or _is_series(b):
        ref = a if _is_series(a) else b
        return _lift(a, ref) / _lift(b, ref)
    return a / b


def matmul(x, w):
    """x @ w with constant (non-series) weights ``w``."""
    if _is_series(x):
        return TSeries([c @ w for c in x.c])
    return x @ w


def add_bias(x, b):
    if _is_series(x):
        return TSeries([x.c[0] + b] + x.c[1:])
    return x + b


def append_time(x, t):
    """Concatenate the scalar time onto the last axis: ``[x ; t]``.

    ``x``: [..., D] (array or series), ``t``: scalar (array or series).
    Returns [..., D+1].  This is the paper's `W [z ; t]` construction
    (Appendix B.2).
    """
    if _is_series(x) or _is_series(t):
        K = x.order if _is_series(x) else t.order
        xs = x if _is_series(x) else TSeries.constant(x, K)
        ts = t if _is_series(t) else TSeries.constant(jnp.asarray(t), K)
        out = []
        for cx, ct in zip(xs.c, ts.c):
            tcol = jnp.broadcast_to(ct, cx.shape[:-1] + (1,))
            out.append(jnp.concatenate([cx, tcol], axis=-1))
        return TSeries(out)
    tcol = jnp.broadcast_to(jnp.asarray(t, dtype=x.dtype), x.shape[:-1] + (1,))
    return jnp.concatenate([x, tcol], axis=-1)


# -- nonlinear ---------------------------------------------------------------

def tanh(x):
    return T.t_tanh(x) if _is_series(x) else jnp.tanh(x)


def sigmoid(x):
    return T.t_sigmoid(x) if _is_series(x) else jax.nn.sigmoid(x)


def exp(x):
    return T.t_exp(x) if _is_series(x) else jnp.exp(x)


def log(x):
    return T.t_log(x) if _is_series(x) else jnp.log(x)


def sqrt(x):
    return T.t_sqrt(x) if _is_series(x) else jnp.sqrt(x)


def sin(x):
    return T.t_sin(x) if _is_series(x) else jnp.sin(x)


def cos(x):
    return T.t_cos(x) if _is_series(x) else jnp.cos(x)


def softplus(x):
    return T.t_softplus(x) if _is_series(x) else jax.nn.softplus(x)


def square(x):
    return mul(x, x)
