"""Regularizer integrands: TayNODE `R_K` (eq. 1) and the RNODE baselines
`K(theta)` (eq. 3) and `B(theta)` (eq. 4) of Finlay et al. (2020).

All integrands are dimension-normalized (Appendix B) and return one value
per batch element; the caller integrates them along the trajectory by
augmenting the ODE state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import taylor as T


def taynode_integrand(f, z, t, order: int):
    """``||d^order z/dt^order||^2 / D`` along trajectories of dz/dt=f.

    ``f`` must be tmath-generic (consumes TSeries).  z: [B, D] -> [B].
    """
    return T.rk_reg_integrand(f, z, t, order)


def rnode_kinetic(f, z, t):
    """Finlay et al. eq. (3): ``||f||^2 / D`` per batch element."""
    v = f(z, t)
    return jnp.sum(v * v, axis=-1) / v.shape[-1]


def rnode_jacobian(f, z, t, eps):
    """Finlay et al. eq. (4): ``||eps^T grad_z f||^2 / D`` with a fixed
    Rademacher probe ``eps`` (shape of z)."""
    fz = lambda zz: f(zz, t)
    _, vjp = jax.vjp(fz, z)
    (jt,) = vjp(eps)
    return jnp.sum(jt * jt, axis=-1) / jt.shape[-1]


def hutchinson_trace(f, z, t, eps):
    """``eps^T (df/dz) eps`` — unbiased trace estimate for the CNF
    instantaneous change of variables.  z: [B, D] -> [B]."""
    fz = lambda zz: f(zz, t)
    _, jv = jax.jvp(fz, (z,), (eps,))
    return jnp.sum(jv * eps, axis=-1)
