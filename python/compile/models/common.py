"""Shared model plumbing: parameter specs, initializers, optimizers and the
paper's time-appended MLP dynamics block, written tmath-generically so the
same definition is used for plain evaluation and for jet propagation."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .. import tmath as tm


class ParamSpec:
    """An ordered list of named parameter arrays — the single source of truth
    for flattening, artifact input order and the on-disk layout."""

    def __init__(self, entries):
        self.entries = list(entries)  # [(name, shape)]

    @property
    def names(self):
        return [n for n, _ in self.entries]

    @property
    def shapes(self):
        return [s for _, s in self.entries]

    def size(self) -> int:
        return int(sum(int(np.prod(s)) for _, s in self.entries))

    def layout(self):
        """[{name, shape, offset, size}] for the manifest."""
        out, off = [], 0
        for name, shape in self.entries:
            n = int(np.prod(shape))
            out.append({"name": name, "shape": list(shape), "offset": off, "size": n})
            off += n
        return out

    def flatten(self, params):
        return np.concatenate([np.asarray(p, dtype=np.float32).ravel() for p in params])

    def specs(self, dtype=jnp.float32):
        return [jax.ShapeDtypeStruct(s, dtype) for s in self.shapes]


def glorot(rng: np.random.RandomState, shape):
    if len(shape) == 1:
        return np.zeros(shape, dtype=np.float32)
    fan_in, fan_out = shape[0], shape[-1]
    scale = np.sqrt(2.0 / (fan_in + fan_out))
    return (rng.randn(*shape) * scale).astype(np.float32)


def init_params(spec: ParamSpec, seed: int):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(glorot(rng, s)) for s in spec.shapes]


# -- the paper's dynamics MLP (Appendix B.2), tmath-generic ------------------

def mlp_dynamics(w1, b1, w2, b2, z, t, pre_tanh: bool = True):
    """f(z, t) = W2 [tanh(W1 [sigma(z) ; t] + b1) ; t] + b2.

    ``pre_tanh`` applies the paper's input squashing ``z1 = sigma(z)``
    (used for the MNIST classifier; the latent/CNF dynamics skip it).
    Accepts jnp arrays or TSeries for ``z`` and ``t``.
    """
    z1 = tm.tanh(z) if pre_tanh else z
    h = tm.add_bias(tm.matmul(tm.append_time(z1, t), w1), b1)
    h = tm.tanh(h)
    return tm.add_bias(tm.matmul(tm.append_time(h, t), w2), b2)


def mlp3_dynamics(w1, b1, w2, b2, w3, b3, z, t):
    """Three-layer CNF dynamics: two hidden tanh layers, time appended at
    every layer (FFJORD's concat-time conditioning)."""
    h = tm.tanh(tm.add_bias(tm.matmul(tm.append_time(z, t), w1), b1))
    h = tm.tanh(tm.add_bias(tm.matmul(tm.append_time(h, t), w2), b2))
    return tm.add_bias(tm.matmul(tm.append_time(h, t), w3), b3)


# -- optimizers (state kept in Rust between steps, threaded through inputs) --

def sgd_momentum(params, moms, grads, lr, beta=0.9):
    new_m = [beta * m + g for m, g in zip(moms, grads)]
    new_p = [p - lr * m for p, m in zip(params, new_m)]
    return new_p, new_m


def adam(params, ms, vs, grads, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    new_m = [b1 * m + (1 - b1) * g for m, g in zip(ms, grads)]
    new_v = [b2 * v + (1 - b2) * (g * g) for v, g in zip(vs, grads)]
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p = [
        p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        for p, m, v in zip(params, new_m, new_v)
    ]
    return new_p, new_m, new_v
