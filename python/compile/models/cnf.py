"""FFJORD continuous normalizing flow (paper §5.3; Tables 2 and 4; Fig 5).

Density estimation by integrating data through learned dynamics while
accumulating the instantaneous change of variables with a Hutchinson trace
estimator.  Two configurations:

  * ``tab``  — tabular (MINIBOONE-like synthetic, d=8), Table 4
  * ``img``  — image (8x8 synthetic digits, d=64), Table 2

Regularizer variants: none, RNODE (Finlay et al.: kinetic + Jacobian), and
TayNODE ``R_K`` on the flow state z(t).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import regularizers as R
from ..odeint import odeint_grid
from .common import ParamSpec, init_params, mlp3_dynamics, adam

CONFIGS = {
    "tab": {"d": 8, "h": 64, "batch": 256},
    "img": {"d": 64, "h": 96, "batch": 64},
}


def param_spec(cfg: str) -> ParamSpec:
    d, h = CONFIGS[cfg]["d"], CONFIGS[cfg]["h"]
    return ParamSpec([
        ("w1", (d + 1, h)), ("b1", (h,)),
        ("w2", (h + 1, h)), ("b2", (h,)),
        ("w3", (h + 1, d)), ("b3", (d,)),
    ])


def init(cfg: str, seed: int = 0):
    return init_params(param_spec(cfg), seed)


def dynamics_fn(w1, b1, w2, b2, w3, b3):
    return lambda z, t: mlp3_dynamics(w1, b1, w2, b2, w3, b3, z, t)


def dynamics(w1, b1, w2, b2, w3, b3, z, t):
    """Raw flow dynamics (z only) for Rust-side probing."""
    return dynamics_fn(w1, b1, w2, b2, w3, b3)(z, t)


def aug_dynamics(w1, b1, w2, b2, w3, b3, state, t, eps):
    """The full CNF system the Rust adaptive solver integrates at eval time.

    state: [B, d+4] = [z | logdet r2 kin jac].  d logdet/dt = eps^T J eps
    (Hutchinson); the remaining columns integrate the table-reported
    regularizer quantities R_2, K, B along the trajectory.
    """
    d = w1.shape[0] - 1
    z = state[:, :d]
    f = dynamics_fn(w1, b1, w2, b2, w3, b3)
    dz = f(z, t)
    tr = R.hutchinson_trace(f, z, t, eps)
    cols = [
        tr,
        R.taynode_integrand(f, z, t, 2),
        R.rnode_kinetic(f, z, t),
        R.rnode_jacobian(f, z, t, eps),
    ]
    return jnp.concatenate([dz] + [c[:, None] for c in cols], axis=1)


def logprob_from_state(z1, logdet):
    """log p(x) = log N(z(1); 0, I) + integral of trace (both per-example)."""
    d = z1.shape[-1]
    logpz = -0.5 * jnp.sum(z1 ** 2, axis=-1) - 0.5 * d * math.log(2 * math.pi)
    return logpz + logdet


def nll_metrics(z1, logdet):
    """Exported: (z1 [B,d], logdet [B]) -> (nll_nats_mean, bits_per_dim)."""
    lp = logprob_from_state(z1, logdet)
    nll = -jnp.mean(lp)
    d = z1.shape[-1]
    bpd = nll / (d * math.log(2.0))
    return nll, bpd


def make_train_step(cfg: str, reg: str = "none", reg_order: int = 2,
                    steps: int = 8):
    """Exported CNF train step (Adam).

    Inputs: 6 params, 6 adam-m, 6 adam-v, x [B,d], eps [B,d] (Hutchinson +
    RNODE probe), lam, lr, step.  Outputs: params, m, v, loss(nll), bpd,
    reg_mean.
    """
    d = CONFIGS[cfg]["d"]

    def train_step(w1, b1, w2, b2, w3, b3,
                   m1, m2, m3, m4, m5, m6,
                   v1, v2, v3, v4, v5, v6,
                   x, eps, lam, lr, step):
        params = [w1, b1, w2, b2, w3, b3]
        ms = [m1, m2, m3, m4, m5, m6]
        vs = [v1, v2, v3, v4, v5, v6]

        def loss_fn(pl):
            f = dynamics_fn(*pl)

            def aug(state, t):
                z, ld, r = state
                dz = f(z, t)
                tr = R.hutchinson_trace(f, z, t, eps)
                if reg == "taynode":
                    dr = R.taynode_integrand(f, z, t, reg_order)
                elif reg == "rnode":
                    dr = R.rnode_kinetic(f, z, t) + R.rnode_jacobian(f, z, t, eps)
                else:
                    dr = jnp.zeros_like(r)
                return (dz, tr, dr)

            zero = jnp.zeros((x.shape[0],), dtype=x.dtype)
            z1, logdet, r1 = odeint_grid(aug, (x, zero, zero), 0.0, 1.0, steps)
            nll, bpd = nll_metrics(z1, logdet)
            rbar = jnp.mean(r1)
            return nll + lam * rbar, (nll, bpd, rbar)

        (loss, (nll, bpd, rbar)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_m, new_v = adam(params, ms, vs, grads, lr, step)
        return (*new_p, *new_m, *new_v, nll, bpd, rbar)

    return train_step
