"""Toy 1-D neural ODE (paper Figs 1 and 9).

Fits the map ``z(t1) = z(t0) + z(t0)^3`` with an MLP-parameterized ODE;
regularizing ``R_3`` (or ``R_6`` for Fig 9) yields dynamics that are far
cheaper for an adaptive solver, with the same fit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import regularizers as R
from ..odeint import odeint_grid
from .common import ParamSpec, init_params, mlp_dynamics, sgd_momentum

D = 1
H = 32
BATCH = 128


def param_spec() -> ParamSpec:
    return ParamSpec([
        ("w1", (D + 1, H)),
        ("b1", (H,)),
        ("w2", (H + 1, D)),
        ("b2", (D,)),
    ])


def init(seed: int = 0):
    return init_params(param_spec(), seed)


def dynamics_fn(w1, b1, w2, b2):
    return lambda z, t: mlp_dynamics(w1, b1, w2, b2, z, t, pre_tanh=False)


def dynamics(w1, b1, w2, b2, z, t):
    """Exported raw-dynamics entry point (called by Rust adaptive solvers)."""
    return dynamics_fn(w1, b1, w2, b2)(z, t)


def make_train_step(reg_order: int = 0, steps: int = 16, method: str = "rk4"):
    """reg_order = 0 disables the regularizer (plain MSE fit)."""

    def train_step(w1, b1, w2, b2, m1, m2, m3, m4, x, lam, lr):
        params = [w1, b1, w2, b2]
        moms = [m1, m2, m3, m4]
        target = x + x ** 3

        def loss_fn(plist):
            f = dynamics_fn(*plist)

            def aug(state, t):
                z, r = state
                dz = f(z, t)
                if reg_order > 0:
                    dr = R.taynode_integrand(f, z, t, reg_order)
                else:
                    dr = jnp.zeros_like(r)
                return (dz, dr)

            r0 = jnp.zeros((x.shape[0],), dtype=x.dtype)
            z1, r1 = odeint_grid(aug, (x, r0), 0.0, 1.0, steps, method)
            mse = jnp.mean((z1 - target) ** 2)
            rbar = jnp.mean(r1)
            return mse + lam * rbar, (mse, rbar)

        (loss, (mse, rbar)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_m = sgd_momentum(params, moms, grads, lr)
        return (*new_p, *new_m, loss, mse, rbar)

    return train_step
