"""Latent ODE for irregular time series (paper §5.2, Fig 4, Fig 12).

The Rubanova et al. (2019) architecture, scaled for the CPU testbed: a GRU
recognition network consumes the (masked) observation sequence backwards and
produces q(z0); the latent state evolves under MLP ODE dynamics; a decoder
maps latent states to observations.  The paper's PhysioNet preprocessing
quantizes observations to a shared hourly grid — our synthetic clinical
generator (``rust/src/data/physionet_sim.rs``) does the same, so all
trajectories share the T-point grid and irregularity enters through the
per-feature observation mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import regularizers as R
from ..odeint import odeint_grid_traj
from .common import ParamSpec, init_params, mlp_dynamics, adam

F = 8       # observed features
T = 16      # shared time grid (t in [0, 1])
L = 10      # latent dimension
GH = 40     # GRU hidden
DH = 40     # dynamics hidden
DEC = 32    # decoder hidden
BATCH = 64
SIGMA = 0.5  # observation noise for the Gaussian likelihood

HYPER = {"f": F, "t": T, "l": L, "gh": GH, "dh": DH, "dec": DEC,
         "batch": BATCH, "sigma": SIGMA}

IN = 2 * F  # GRU input: [x * mask ; mask]


def param_spec() -> ParamSpec:
    return ParamSpec([
        # GRU recognition network
        ("wz", (IN, GH)), ("uz", (GH, GH)), ("bz", (GH,)),
        ("wr", (IN, GH)), ("ur", (GH, GH)), ("br", (GH,)),
        ("wg", (IN, GH)), ("ug", (GH, GH)), ("bg", (GH,)),
        ("wmu", (GH, L)), ("bmu", (L,)),
        ("wlv", (GH, L)), ("blv", (L,)),
        # latent dynamics
        ("w1", (L + 1, DH)), ("b1", (DH,)),
        ("w2", (DH + 1, L)), ("b2", (L,)),
        # decoder
        ("wd1", (L, DEC)), ("bd1", (DEC,)),
        ("wd2", (DEC, F)), ("bd2", (F,)),
    ])


N_PARAMS = len(param_spec().entries)


def init(seed: int = 0):
    return init_params(param_spec(), seed)


def _gru_cell(p, h, inp):
    zg = jax.nn.sigmoid(inp @ p["wz"] + h @ p["uz"] + p["bz"])
    rg = jax.nn.sigmoid(inp @ p["wr"] + h @ p["ur"] + p["br"])
    g = jnp.tanh(inp @ p["wg"] + (rg * h) @ p["ug"] + p["bg"])
    return (1.0 - zg) * h + zg * g


def encode_fn(p, x, mask):
    """Run the GRU backwards over the grid; return (mu, logvar) of q(z0).

    x, mask: [B, T, F]."""
    B = x.shape[0]
    h0 = jnp.zeros((B, GH), dtype=x.dtype)
    seq = jnp.concatenate([x * mask, mask], axis=-1)  # [B, T, 2F]
    rev = seq[:, ::-1, :]

    def body(h, xt):
        return _gru_cell(p, h, xt), None

    hT, _ = jax.lax.scan(body, h0, jnp.transpose(rev, (1, 0, 2)))
    mu = hT @ p["wmu"] + p["bmu"]
    logvar = hT @ p["wlv"] + p["blv"]
    return mu, logvar


def _pdict(plist):
    return dict(zip(param_spec().names, plist))


def encode(*args):
    """Exported: (21 params, x, mask) -> (mu, logvar)."""
    plist, (x, mask) = args[:N_PARAMS], args[N_PARAMS:]
    return encode_fn(_pdict(plist), x, mask)


def dynamics_fn(p):
    return lambda z, t: mlp_dynamics(p["w1"], p["b1"], p["w2"], p["b2"], z, t,
                                     pre_tanh=False)


def dynamics(w1, b1, w2, b2, z, t):
    """Raw latent dynamics for the Rust adaptive solver (NFE measurement)."""
    return mlp_dynamics(w1, b1, w2, b2, z, t, pre_tanh=False)


def decode_fn(p, z):
    h = jnp.tanh(z @ p["wd1"] + p["bd1"])
    return h @ p["wd2"] + p["bd2"]


def decode(wd1, bd1, wd2, bd2, z):
    """Exported: decode one grid-point's latent state. z: [B, L] -> [B, F]."""
    h = jnp.tanh(z @ wd1 + bd1)
    return h @ wd2 + bd2


def traj_metrics(wd1, bd1, wd2, bd2, ztraj, x, mask):
    """Masked NLL and MSE of a decoded latent trajectory.

    ztraj: [T, B, L] (as produced by the Rust solver's grid outputs),
    x, mask: [B, T, F]."""
    p = {"wd1": wd1, "bd1": bd1, "wd2": wd2, "bd2": bd2}
    xhat = decode_fn(p, ztraj)              # [T, B, F]
    xhat = jnp.transpose(xhat, (1, 0, 2))   # [B, T, F]
    se = (xhat - x) ** 2 * mask
    nobs = jnp.maximum(jnp.sum(mask), 1.0)
    mse = jnp.sum(se) / nobs
    nll = jnp.sum(se) / (2 * SIGMA ** 2) / nobs
    return nll, mse


def make_train_step(reg: str = "none", reg_order: int = 2, substeps: int = 1):
    """Exported train step (Adam).

    Inputs: 21 params, 21 adam-m, 21 adam-v, x [B,T,F], mask [B,T,F],
    eps_z [B,L] (posterior sample noise), lam, lr, step (adam t, f32).
    Outputs: 21 params, 21 m, 21 v, loss, nll, reg_mean, kl, mse.
    The latent trajectory is integrated on the observation grid with
    ``substeps`` RK4 steps per interval.
    """
    spec = param_spec()
    P = N_PARAMS

    def train_step(*args):
        plist = list(args[:P])
        ms = list(args[P:2 * P])
        vs = list(args[2 * P:3 * P])
        x, mask, eps_z, lam, lr, step = args[3 * P:]

        def loss_fn(pl):
            p = _pdict(pl)
            mu, logvar = encode_fn(p, x, mask)
            z0 = mu + jnp.exp(0.5 * logvar) * eps_z
            f = dynamics_fn(p)

            def aug(state, t):
                z, r = state
                dz = f(z, t)
                if reg == "taynode":
                    dr = R.taynode_integrand(f, z, t, reg_order)
                else:
                    dr = jnp.zeros_like(r)
                return (dz, dr)

            r0 = jnp.zeros((x.shape[0],), dtype=x.dtype)
            steps = (T - 1) * substeps
            _, traj = odeint_grid_traj(aug, (z0, r0), 0.0, 1.0, steps)
            ztraj = traj[0][substeps - 1::substeps]     # [T-1, B, L]
            ztraj = jnp.concatenate([z0[None], ztraj], axis=0)  # [T, B, L]
            r1 = traj[1][-1]
            nll, mse = traj_metrics(p["wd1"], p["bd1"], p["wd2"], p["bd2"],
                                    ztraj, x, mask)
            kl = -0.5 * jnp.mean(jnp.sum(1 + logvar - mu ** 2 - jnp.exp(logvar),
                                         axis=-1))
            rbar = jnp.mean(r1)
            return nll + 0.1 * kl + lam * rbar, (nll, rbar, kl, mse)

        (loss, (nll, rbar, kl, mse)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(plist)
        new_p, new_m, new_v = adam(plist, ms, vs, grads, lr, step)
        return (*new_p, *new_m, *new_v, loss, nll, rbar, kl, mse)

    return train_step
