from . import toy, mnist, latent_ode, cnf

__all__ = ["toy", "mnist", "latent_ode", "cnf"]
