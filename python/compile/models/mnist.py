"""MNIST ODE classifier (paper §5.1, Appendix B.2; Figs 3, 5-8, 10, 11 and
Table 3).

A flattened image is integrated through MLP dynamics
``f(z, t) = W2 [tanh(W1 [tanh(z) ; t] + b1) ; t] + b2`` and classified by a
linear head on the final state.  Input is 14x14 (D=196) — the procedural
digit generator in ``rust/src/data/synth_mnist.rs`` renders at this
resolution (DESIGN.md §3 substitutions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import regularizers as R
from ..kernels import fused_mlp
from ..odeint import odeint_grid
from .common import ParamSpec, init_params, mlp_dynamics, sgd_momentum

D = 196
H = 100
N_CLASS = 10
BATCH = 64

HYPER = {"d": D, "h": H, "n_class": N_CLASS, "batch": BATCH}


def param_spec() -> ParamSpec:
    return ParamSpec([
        ("w1", (D + 1, H)),
        ("b1", (H,)),
        ("w2", (H + 1, D)),
        ("b2", (D,)),
        ("wh", (D, N_CLASS)),
        ("bh", (N_CLASS,)),
    ])


def init(seed: int = 0):
    return init_params(param_spec(), seed)


def dynamics_fn(w1, b1, w2, b2):
    return lambda z, t: mlp_dynamics(w1, b1, w2, b2, z, t, pre_tanh=True)


def dynamics(w1, b1, w2, b2, z, t):
    """Raw dynamics — the Rust adaptive solver's callee (one call = one NFE)."""
    return dynamics_fn(w1, b1, w2, b2)(z, t)


def dynamics_pallas(w1, b1, w2, b2, z, t):
    """Same dynamics through the fused Pallas kernel (L1 hot path).

    The kernel fuses tanh -> GEMM -> tanh -> GEMM so the [B, H] activation
    never leaves VMEM; numerics are asserted equal to :func:`dynamics` in
    ``python/tests/test_kernels.py``."""
    return fused_mlp(z, t, w1, b1, w2, b2)


def head(wh, bh, z):
    return z @ wh + bh


def head_metrics(wh, bh, z1, labels):
    """Cross-entropy (mean) and error count from the final ODE state.

    Exported as ``mnist_head`` so Rust can compute classification metrics
    after its own adaptive solve.  ``labels``: int32 [B]."""
    logits = head(wh, bh, z1)
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, N_CLASS, dtype=logits.dtype)
    ce = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    err = jnp.sum((jnp.argmax(logits, axis=-1) != labels).astype(jnp.float32))
    return ce, err


def aug_dynamics(w1, b1, w2, b2, state, t, eps):
    """Instrumented dynamics for evaluation-time measurement.

    ``state``: [B, D+6] = [z | r1 r2 r3 r4 kin jac] accumulators.  Returns
    the time-derivative of the full state, so the Rust adaptive solver can
    integrate the regularizer quantities the paper tables report
    (R_2, and Finlay et al.'s K and B) plus R_1..R_4 for Fig 7.
    """
    z = state[:, :D]
    f = dynamics_fn(w1, b1, w2, b2)
    dz = f(z, t)
    cols = [
        R.taynode_integrand(f, z, t, 1),
        R.taynode_integrand(f, z, t, 2),
        R.taynode_integrand(f, z, t, 3),
        R.taynode_integrand(f, z, t, 4),
        R.rnode_kinetic(f, z, t),
        R.rnode_jacobian(f, z, t, eps),
    ]
    return jnp.concatenate([dz] + [c[:, None] for c in cols], axis=1)


def make_train_step(reg: str = "none", reg_order: int = 3, steps: int = 8,
                    method: str = "rk4"):
    """Build the exported train step.

    reg in {"none", "taynode", "rnode"}.  Signature (order = artifact input
    order): 6 params, 6 momenta, x [B,D], labels int32 [B], eps [B,D]
    (Rademacher probe, used by rnode only), lam, lr.  Returns 6 params,
    6 momenta, loss, ce, reg_mean.
    """

    def train_step(w1, b1, w2, b2, wh, bh,
                   mw1, mb1, mw2, mb2, mwh, mbh,
                   x, labels, eps, lam, lr):
        params = [w1, b1, w2, b2, wh, bh]
        moms = [mw1, mb1, mw2, mb2, mwh, mbh]

        def loss_fn(plist):
            pw1, pb1, pw2, pb2, pwh, pbh = plist
            f = dynamics_fn(pw1, pb1, pw2, pb2)

            def aug(state, t):
                z, r = state
                dz = f(z, t)
                if reg == "taynode":
                    dr = R.taynode_integrand(f, z, t, reg_order)
                elif reg == "rnode":
                    dr = R.rnode_kinetic(f, z, t) + R.rnode_jacobian(f, z, t, eps)
                else:
                    dr = jnp.zeros_like(r)
                return (dz, dr)

            r0 = jnp.zeros((x.shape[0],), dtype=x.dtype)
            z1, r1 = odeint_grid(aug, (x, r0), 0.0, 1.0, steps, method)
            ce, _ = head_metrics(pwh, pbh, z1, labels)
            rbar = jnp.mean(r1)
            return ce + lam * rbar, (ce, rbar)

        (loss, (ce, rbar)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_m = sgd_momentum(params, moms, grads, lr)
        return (*new_p, *new_m, loss, ce, rbar)

    return train_step
