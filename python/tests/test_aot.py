"""AOT export pipeline: catalog integrity, HLO-text emission, manifest
consistency with the on-disk parameter blobs."""

import json
import os

import jax
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.model import MODEL_SPECS, catalog

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_catalog_unique_names_and_roles():
    arts = catalog()
    names = [a.name for a in arts]
    assert len(names) == len(set(names))
    for a in arts:
        for role, name, s in a.inputs:
            assert role.split(":")[0] in {"param", "opt", "batch", "scalar", "rng"}
            assert all(dim > 0 for dim in s.shape) or s.shape == ()


def test_param_specs_match_models():
    for mname, (pspec, init_fn, hyper) in MODEL_SPECS.items():
        params = init_fn(0)
        assert len(params) == len(pspec.entries)
        for p, (n, s) in zip(params, pspec.entries):
            assert tuple(p.shape) == tuple(s), (mname, n)
        flat = pspec.flatten(params)
        assert flat.size == pspec.size()


def test_train_artifacts_roundtrip_params():
    """Every train artifact must output exactly its param+opt inputs first
    (the Rust trainer feeds outputs back as next-step inputs)."""
    for a in catalog():
        if a.kind != "train":
            continue
        n_state = sum(1 for r, _, _ in a.inputs
                      if r.startswith("param") or r.startswith("opt"))
        outs = jax.eval_shape(a.fn, *[s for _, _, s in a.inputs])
        assert len(outs) > n_state, a.name
        state_in = [s for r, _, s in a.inputs
                    if r.startswith("param") or r.startswith("opt")]
        for i, si in enumerate(state_in):
            assert tuple(outs[i].shape) == tuple(si.shape), (a.name, i)


def test_hlo_text_emission_small():
    """The text path emits a parsable HLO module for a tiny function."""
    import jax.numpy as jnp

    def fn(x):
        return (jnp.tanh(x) @ jnp.ones((4, 2), jnp.float32),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((3, 4), jnp.float32))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_disk():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, e in man["executables"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(64)
        assert "HloModule" in head, name
    for mname, m in man["models"].items():
        blob = np.fromfile(os.path.join(ART, m["params"]["file"]),
                           dtype="<f4")
        assert blob.size == m["params"]["total"], mname
        last = m["params"]["layout"][-1]
        assert last["offset"] + last["size"] == blob.size
