"""Model-level tests: shapes, losses decrease under a few steps of the
exported train functions, regularizers behave as the paper describes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import cnf, latent_ode, mnist, toy
from compile import regularizers as R


def test_toy_train_reduces_loss():
    step = toy.make_train_step(reg_order=0, steps=8)
    params = toy.init(0)
    moms = [jnp.zeros_like(p) for p in params]
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.uniform(-1.5, 1.5, (toy.BATCH, 1)).astype(np.float32))
    first = None
    jstep = jax.jit(step)
    for i in range(30):
        out = jstep(*params, *moms, x, jnp.float32(0.0), jnp.float32(0.05))
        params, moms = list(out[:4]), list(out[4:8])
        loss = float(out[8])
        if first is None:
            first = loss
    assert loss < first * 0.7, (first, loss)


def test_toy_regularized_shrinks_r3():
    """Training with lambda > 0 yields smaller integrated R_3 than lambda=0
    (the mechanism behind Fig 1)."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.uniform(-1.5, 1.5, (toy.BATCH, 1)).astype(np.float32))

    def run(lam):
        step = jax.jit(toy.make_train_step(reg_order=3, steps=8))
        params = toy.init(0)
        moms = [jnp.zeros_like(p) for p in params]
        for _ in range(40):
            out = step(*params, *moms, x, jnp.float32(lam), jnp.float32(0.05))
            params, moms = list(out[:4]), list(out[4:8])
        return float(out[10])  # rbar

    assert run(1.0) < run(0.0)


def test_mnist_shapes_and_step():
    step = jax.jit(mnist.make_train_step(reg="taynode", reg_order=2, steps=2))
    params = mnist.init(0)
    moms = [jnp.zeros_like(p) for p in params]
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(mnist.BATCH, mnist.D).astype(np.float32))
    y = jnp.asarray(rng.randint(0, 10, mnist.BATCH).astype(np.int32))
    eps = jnp.asarray(np.sign(rng.randn(mnist.BATCH, mnist.D)).astype(np.float32))
    out = step(*params, *moms, x, y, eps, jnp.float32(0.01), jnp.float32(0.1))
    assert len(out) == 15
    loss, ce, rbar = map(float, out[12:])
    assert np.isfinite(loss) and np.isfinite(ce) and rbar >= 0
    # one step with lr>0 must change parameters
    assert not np.allclose(np.asarray(out[0]), np.asarray(params[0]))


def test_mnist_aug_dynamics_columns():
    params = mnist.init(0)
    rng = np.random.RandomState(2)
    B, D = mnist.BATCH, mnist.D
    state = jnp.asarray(np.concatenate(
        [rng.randn(B, D), np.zeros((B, 6))], axis=1).astype(np.float32))
    eps = jnp.asarray(np.sign(rng.randn(B, D)).astype(np.float32))
    out = mnist.aug_dynamics(*params[:4], state, jnp.float32(0.3), eps)
    assert out.shape == (B, D + 6)
    cols = np.asarray(out[:, D:])
    assert np.all(cols[:, :4] >= 0)   # R_1..R_4 integrands are norms
    assert np.all(cols[:, 4:] >= 0)   # kinetic & jacobian integrands too
    # R_1 must equal the kinetic energy ||f||^2/D (identical definitions)
    np.testing.assert_allclose(cols[:, 0], cols[:, 4], rtol=1e-4, atol=1e-5)


def test_mnist_head_metrics():
    params = mnist.init(0)
    rng = np.random.RandomState(3)
    z = jnp.asarray(rng.randn(mnist.BATCH, mnist.D).astype(np.float32))
    y = jnp.asarray(rng.randint(0, 10, mnist.BATCH).astype(np.int32))
    ce, err = mnist.head_metrics(params[4], params[5], z, y)
    assert 0 <= float(err) <= mnist.BATCH
    assert float(ce) > 0
    # CE of uniform logits is log(10)
    ce0, _ = mnist.head_metrics(jnp.zeros_like(params[4]),
                                jnp.zeros_like(params[5]), z, y)
    np.testing.assert_allclose(float(ce0), np.log(10.0), rtol=1e-5)


def test_latent_encode_decode_shapes():
    params = latent_ode.init(0)
    p = dict(zip(latent_ode.param_spec().names, params))
    rng = np.random.RandomState(4)
    B, T, F, L = latent_ode.BATCH, latent_ode.T, latent_ode.F, latent_ode.L
    x = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
    m = jnp.asarray((rng.rand(B, T, F) < 0.5).astype(np.float32))
    mu, lv = latent_ode.encode_fn(p, x, m)
    assert mu.shape == (B, L) and lv.shape == (B, L)
    xhat = latent_ode.decode_fn(p, mu)
    assert xhat.shape == (B, F)


def test_latent_train_step_runs_and_learns():
    step = jax.jit(latent_ode.make_train_step(reg="taynode", reg_order=2))
    params = latent_ode.init(0)
    P = len(params)
    ms = [jnp.zeros_like(q) for q in params]
    vs = [jnp.zeros_like(q) for q in params]
    rng = np.random.RandomState(5)
    B, T, F, L = latent_ode.BATCH, latent_ode.T, latent_ode.F, latent_ode.L
    ts = np.linspace(0, 1, T, dtype=np.float32)
    x = jnp.asarray(np.sin(2 * np.pi * ts)[None, :, None]
                    * np.ones((B, 1, F), np.float32))
    m = jnp.ones((B, T, F), jnp.float32)
    eps = jnp.zeros((B, L), jnp.float32)
    losses = []
    for i in range(10):
        out = step(*params, *ms, *vs, x, m, eps,
                   jnp.float32(0.0), jnp.float32(1e-2), jnp.float32(i + 1))
        params = list(out[:P])
        ms, vs = list(out[P:2 * P]), list(out[2 * P:3 * P])
        losses.append(float(out[3 * P]))
    assert losses[-1] < losses[0]


def test_cnf_logprob_standard_normal():
    """With zero dynamics the flow is the identity: log p must equal the
    base log-density exactly."""
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(16, 8).astype(np.float32))
    nll, bpd = cnf.nll_metrics(x, jnp.zeros((16,), jnp.float32))
    want = -np.mean(-0.5 * np.sum(np.asarray(x) ** 2, 1)
                    - 0.5 * 8 * np.log(2 * np.pi))
    np.testing.assert_allclose(float(nll), want, rtol=1e-5)
    np.testing.assert_allclose(float(bpd), want / (8 * np.log(2)), rtol=1e-5)


def test_cnf_train_step_improves_nll():
    step = jax.jit(cnf.make_train_step("tab", reg="none", steps=4))
    params = cnf.init("tab", 0)
    ms = [jnp.zeros_like(q) for q in params]
    vs = [jnp.zeros_like(q) for q in params]
    rng = np.random.RandomState(7)
    B, d = cnf.CONFIGS["tab"]["batch"], cnf.CONFIGS["tab"]["d"]
    # data: a shifted/scaled gaussian the flow must learn to whiten
    x = jnp.asarray((rng.randn(B, d) * 0.5 + 1.0).astype(np.float32))
    eps = jnp.asarray(np.sign(rng.randn(B, d)).astype(np.float32))
    nlls = []
    for i in range(25):
        out = step(*params, *ms, *vs, x, eps,
                   jnp.float32(0.0), jnp.float32(5e-3), jnp.float32(i + 1))
        params, ms, vs = list(out[:6]), list(out[6:12]), list(out[12:18])
        nlls.append(float(out[18]))
    assert nlls[-1] < nlls[0]


def test_cnf_hutchinson_unbiased_on_linear():
    """For linear dynamics f = A z the Hutchinson estimate with Rademacher
    probes has expectation tr(A); average over probes and check."""
    rng = np.random.RandomState(8)
    A = (rng.randn(6, 6) * 0.3).astype(np.float32)
    f = lambda z, t: z @ jnp.asarray(A.T)
    z = jnp.asarray(rng.randn(1, 6).astype(np.float32))
    ests = []
    for s in range(400):
        e = jnp.asarray(np.sign(np.random.RandomState(s).randn(1, 6))
                        .astype(np.float32))
        ests.append(float(R.hutchinson_trace(f, z, 0.0, e)[0]))
    np.testing.assert_allclose(np.mean(ests), np.trace(A), atol=0.05)
