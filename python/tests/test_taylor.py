"""Taylor-mode AD (compile.taylor) vs jax.experimental.jet and finite
differences — validates every propagation rule in Table 1 / Appendix A and
the Algorithm 1 recursion."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import jet as jjet

from compile import taylor as T
from compile import tmath as tm

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape):
    return jnp.asarray(rng.randn(*shape).astype(np.float32))


def _series(rng, shape, K):
    return [_rand(rng, shape) for _ in range(K)]


def check_against_jax(f_tm, f_jnp, x0, series, rtol=2e-3, atol=2e-3):
    y0a, ysa = T.jet(f_tm, (x0,), (series,))
    y0b, ysb = jjet.jet(f_jnp, (x0,), (series,))
    np.testing.assert_allclose(y0a, y0b, rtol=rtol, atol=atol)
    for k, (a, b) in enumerate(zip(ysa, ysb)):
        np.testing.assert_allclose(a, b, rtol=rtol, atol=atol,
                                   err_msg=f"order {k+1}")


UNARY_CASES = [
    ("tanh", tm.tanh, jnp.tanh, None),
    ("sigmoid", tm.sigmoid, jax.nn.sigmoid, None),
    ("exp", tm.exp, jnp.exp, None),
    ("sin", tm.sin, jnp.sin, None),
    ("cos", tm.cos, jnp.cos, None),
    # jax.experimental.jet cannot trace jax.nn.softplus (custom_jvp), so the
    # reference is the explicit composition.
    ("softplus", tm.softplus, lambda x: jnp.log(1.0 + jnp.exp(x)), None),
    ("log", tm.log, jnp.log, "pos"),
    ("sqrt", tm.sqrt, jnp.sqrt, "pos"),
]


@pytest.mark.parametrize("name,f_tm,f_jnp,domain", UNARY_CASES)
@pytest.mark.parametrize("order", [1, 2, 3, 5])
def test_unary_rules_vs_jax(name, f_tm, f_jnp, domain, order):
    rng = np.random.RandomState(hash(name) % 2**31)
    x0 = _rand(rng, (7,))
    if domain == "pos":
        x0 = jnp.abs(x0) + 0.5
    series = _series(rng, (7,), order)
    check_against_jax(f_tm, f_jnp, x0, series)


@pytest.mark.parametrize("order", [1, 2, 4])
def test_mul_div_rules(order):
    rng = np.random.RandomState(3)
    x0 = _rand(rng, (5,))
    series = _series(rng, (5,), order)
    check_against_jax(lambda x: tm.mul(x, x) + tm.div(tm.sin(x), tm.exp(x)),
                      lambda x: x * x + jnp.sin(x) / jnp.exp(x),
                      x0, series)


def test_composition_deep():
    rng = np.random.RandomState(4)
    x0 = _rand(rng, (6,))
    series = _series(rng, (6,), 4)
    check_against_jax(
        lambda x: tm.tanh(tm.sigmoid(tm.sin(tm.mul(x, 0.7)) + tm.cos(x))),
        lambda x: jnp.tanh(jax.nn.sigmoid(jnp.sin(0.7 * x) + jnp.cos(x))),
        x0, series)


def test_matmul_and_time_append():
    rng = np.random.RandomState(5)
    W = _rand(rng, (4, 3))
    x0 = _rand(rng, (2, 3))
    series = _series(rng, (2, 3), 3)

    def f_tm(x):
        return tm.matmul(tm.append_time(tm.tanh(x), 0.5), jnp.ones((4, 2))) \
            if False else tm.tanh(tm.matmul(x, W.T))

    def f_jnp(x):
        return jnp.tanh(x @ W.T)

    check_against_jax(f_tm, f_jnp, x0, series)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5), st.integers(1, 16), st.integers(0, 10_000))
def test_mul_rule_hypothesis(order, n, seed):
    """Property: our Cauchy-product rule matches jax.jet for products."""
    rng = np.random.RandomState(seed)
    x0 = _rand(rng, (n,))
    series = _series(rng, (n,), order)
    check_against_jax(lambda x: tm.mul(x, tm.tanh(x)),
                      lambda x: x * jnp.tanh(x), x0, series)


def test_tseries_ring_axioms():
    rng = np.random.RandomState(7)
    a = T.TSeries(_series(rng, (4,), 4))
    b = T.TSeries(_series(rng, (4,), 4))
    c = T.TSeries(_series(rng, (4,), 4))
    ab = a * b
    ba = b * a
    for x, y in zip(ab.c, ba.c):
        np.testing.assert_allclose(x, y, rtol=1e-5)
    lhs = (a * (b + c)).c
    rhs = (a * b + a * c).c
    for x, y in zip(lhs, rhs):
        np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)


def test_div_is_mul_inverse():
    rng = np.random.RandomState(8)
    a = T.TSeries([_rand(rng, (5,)) + 3.0] + _series(rng, (5,), 3))
    one = (a / a).c
    np.testing.assert_allclose(one[0], np.ones(5), rtol=1e-5)
    for k in range(1, 4):
        np.testing.assert_allclose(one[k], np.zeros(5), atol=1e-5)


def test_sin_cos_pythagorean():
    rng = np.random.RandomState(9)
    z = T.TSeries(_series(rng, (5,), 4))
    s, c = T.t_sin_cos(z)
    ident = (s * s + c * c).c
    np.testing.assert_allclose(ident[0], np.ones(5), rtol=1e-5)
    for k in range(1, z.order + 1):
        np.testing.assert_allclose(ident[k], np.zeros(5), atol=1e-4)


# ---- Algorithm 1: ODE solution coefficients --------------------------------

def test_ode_jet_linear_system():
    """dz/dt = A z has z^(k) = A^k z, checkable in closed form."""
    rng = np.random.RandomState(10)
    A = (rng.randn(3, 3) * 0.5).astype(np.float32)
    z0 = _rand(rng, (2, 3))

    def f(z, t):
        return tm.matmul(z, jnp.asarray(A.T))

    xs = T.ode_jet(f, z0, 0.0, 4)
    expect = np.asarray(z0)
    for k in range(4):
        expect = expect @ A.T
        np.testing.assert_allclose(xs[k], expect, rtol=2e-3, atol=1e-4,
                                   err_msg=f"order {k+1}")


def test_ode_jet_time_dependent():
    """dz/dt = z sin t has the analytic solution z0 exp(cos t0 - cos t)."""
    z0 = jnp.array([[0.7, -0.3]], dtype=jnp.float32)
    t0 = 0.3
    xs = T.ode_jet(lambda z, t: tm.mul(z, tm.sin(t)), z0, t0, 5)

    def zfun(dt):
        return z0 * jnp.exp(-jnp.cos(t0 + dt) + math.cos(t0))

    tang = (jnp.float32(1.0),) + (jnp.float32(0.0),) * 4
    _, sers = jjet.jet(zfun, (jnp.float32(0.0),), (tang,))
    for k in range(5):
        np.testing.assert_allclose(xs[k], sers[k], rtol=2e-3, atol=1e-4)


def test_ode_jet_vs_nested_jvp():
    """Taylor mode and nested JVPs agree (the paper's efficiency claim is
    about cost, not semantics)."""
    rng = np.random.RandomState(11)
    W = jnp.asarray((rng.randn(4, 4) * 0.4).astype(np.float32))

    def f(z, t):
        return tm.tanh(tm.matmul(z, W))

    z0 = _rand(rng, (1, 4))
    a = T.ode_jet(f, z0, 0.0, 4)
    b = T.nested_jvp_coeffs(lambda z, t: jnp.tanh(z @ W), z0, 0.0, 4)
    for k in range(4):
        np.testing.assert_allclose(a[k], b[k], rtol=3e-3, atol=1e-3)


def test_reg_integrand_zero_for_exact_low_order():
    """R_K = 0 for trajectories whose K-th total derivative vanishes:
    constant dynamics give R_2 = 0 (straight lines, paper §3)."""
    z0 = jnp.ones((3, 2), dtype=jnp.float32)
    const = jnp.array([[0.3, -0.7]], dtype=jnp.float32)

    def f(z, t):
        return tm.mul(tm.add(tm.mul(z, 0.0), 1.0), const)

    r2 = T.rk_reg_integrand(f, z0, 0.0, 2)
    np.testing.assert_allclose(r2, np.zeros(3), atol=1e-6)
    r1 = T.rk_reg_integrand(f, z0, 0.0, 1)
    assert float(jnp.min(r1)) > 0.0


def test_jet_is_differentiable():
    """grad flows through the whole Taylor recursion (needed for training)."""
    rng = np.random.RandomState(12)
    W = jnp.asarray((rng.randn(3, 3) * 0.4).astype(np.float32))
    z0 = _rand(rng, (2, 3))

    def loss(W):
        f = lambda z, t: tm.tanh(tm.matmul(z, W))
        return jnp.sum(T.rk_reg_integrand(f, z0, 0.0, 3))

    g = jax.grad(loss)(W)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.max(jnp.abs(g))) > 0.0
