"""Pallas kernels vs pure-jnp oracles (hypothesis sweeps over shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import cauchy_prod, fused_mlp, ref
from compile.models import mnist


def _mlp_weights(rng, D, H):
    w1 = jnp.asarray((rng.randn(D + 1, H) * 0.3).astype(np.float32))
    b1 = jnp.asarray((rng.randn(H) * 0.1).astype(np.float32))
    w2 = jnp.asarray((rng.randn(H + 1, D) * 0.3).astype(np.float32))
    b2 = jnp.asarray((rng.randn(D) * 0.1).astype(np.float32))
    return w1, b1, w2, b2


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 8, 32, 64]),
    d=st.sampled_from([4, 28, 196]),
    h=st.sampled_from([16, 100]),
    block=st.sampled_from([8, 16, 32]),
    t=st.floats(-1.0, 2.0),
    seed=st.integers(0, 10_000),
)
def test_fused_mlp_vs_ref(b, d, h, block, t, seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(b, d).astype(np.float32))
    w1, b1, w2, b2 = _mlp_weights(rng, d, h)
    got = fused_mlp(x, t, w1, b1, w2, b2, block_b=block)
    want = ref.fused_mlp_ref(x, jnp.float32(t), w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 7),
    n=st.sampled_from([1, 16, 128, 384]),
    block=st.sampled_from([32, 128, 256]),
    seed=st.integers(0, 10_000),
)
def test_cauchy_prod_vs_ref(k, n, block, seed):
    rng = np.random.RandomState(seed)
    z = jnp.asarray(rng.randn(k + 1, n).astype(np.float32))
    w = jnp.asarray(rng.randn(k + 1, n).astype(np.float32))
    got = cauchy_prod(z, w, block_n=block)
    want = ref.cauchy_prod_ref(z, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cauchy_prod_is_polynomial_product():
    """Multiplying the coefficient stacks must equal multiplying the
    polynomials and truncating — checked by evaluating at points within the
    radius where truncation error is tiny for short series."""
    rng = np.random.RandomState(0)
    K = 3
    z = rng.randn(K + 1, 4).astype(np.float32) * 0.1
    w = rng.randn(K + 1, 4).astype(np.float32) * 0.1
    y = np.asarray(cauchy_prod(jnp.asarray(z), jnp.asarray(w)))
    # compare against numpy polynomial multiply, truncated
    for col in range(4):
        full = np.polymul(z[::-1, col], w[::-1, col])[::-1][: K + 1]
        np.testing.assert_allclose(y[:, col], full, rtol=1e-4, atol=1e-6)


def test_dynamics_pallas_matches_jnp():
    """The exported pallas dynamics artifact computes exactly the same
    function as the jnp dynamics artifact (L1 vs L2 agreement)."""
    rng = np.random.RandomState(1)
    params = mnist.init(0)
    w1, b1, w2, b2 = params[:4]
    z = jnp.asarray(rng.randn(mnist.BATCH, mnist.D).astype(np.float32))
    a = mnist.dynamics(w1, b1, w2, b2, z, 0.25)
    b = mnist.dynamics_pallas(w1, b1, w2, b2, z, 0.25)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
