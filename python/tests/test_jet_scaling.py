"""§Perf evidence for the paper's §4 claim: Taylor mode computes the K-th
total derivative with polynomial cost, while nested first-order JVPs blow up
exponentially.  We compare *trace sizes* (number of jaxpr equations — a
machine-independent cost proxy) and wall-clock at small K."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import taylor as T
from compile import tmath as tm


def _f_tm(W):
    return lambda z, t: tm.tanh(tm.matmul(z, W))


def _f_jnp(W):
    return lambda z, t: jnp.tanh(z @ W)


def _eqn_count(fn, *args):
    return len(jax.make_jaxpr(fn)(*args).eqns)


def test_taylor_mode_polynomial_trace_growth():
    rng = np.random.RandomState(0)
    W = jnp.asarray((rng.randn(8, 8) * 0.3).astype(np.float32))
    z0 = jnp.asarray(rng.randn(2, 8).astype(np.float32))

    taylor_sizes = []
    nested_sizes = []
    for K in (1, 2, 3, 4, 5):
        taylor_sizes.append(_eqn_count(
            lambda z: T.ode_jet(_f_tm(W), z, 0.0, K)[-1], z0))
        if K <= 4:
            nested_sizes.append(_eqn_count(
                lambda z: T.nested_jvp_coeffs(_f_jnp(W), z, 0.0, K)[-1], z0))

    # Taylor mode: polynomial growth — consecutive ratios *shrink* with K
    # (measured: [2, 12, 34, 73, 134] -> 134/73 ~ 1.8).
    assert taylor_sizes[4] / taylor_sizes[3] < 2.5, taylor_sizes
    # Nested JVPs: exponential growth — each added order multiplies the trace
    # by ~e (measured: [2, 11, 41, 132] -> 132/41 ~ 3.2).
    assert nested_sizes[3] / nested_sizes[2] > 2.5, nested_sizes
    # And the overall K=4/K=2 blowup is decisively worse for nesting.
    r_nested = nested_sizes[3] / nested_sizes[1]
    r_taylor = taylor_sizes[3] / taylor_sizes[1]
    assert r_nested > 1.3 * r_taylor, (nested_sizes, taylor_sizes)


def test_taylor_mode_faster_wallclock_at_k4():
    rng = np.random.RandomState(1)
    W = jnp.asarray((rng.randn(64, 64) * 0.1).astype(np.float32))
    z0 = jnp.asarray(rng.randn(16, 64).astype(np.float32))
    K = 4

    jt = jax.jit(lambda z: T.ode_jet(_f_tm(W), z, 0.0, K)[-1])
    jn = jax.jit(lambda z: T.nested_jvp_coeffs(_f_jnp(W), z, 0.0, K)[-1])
    np.testing.assert_allclose(jt(z0), jn(z0), rtol=5e-3, atol=1e-3)

    def bench(fn):
        fn(z0).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            fn(z0).block_until_ready()
        return time.perf_counter() - t0

    t_taylor, t_nested = bench(jt), bench(jn)
    # compiled XLA fuses aggressively and wall-clock is noisy under load; the
    # load-bearing asymptotic claim is the trace-growth test above.  Here we
    # only require that Taylor mode is not catastrophically slower.
    assert t_taylor < 2.5 * t_nested, (t_taylor, t_nested)
