"""Fixed-grid RK integrators: order-exactness on polynomials and convergence
on smooth problems (mirrors the property tests of the Rust solver suite)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.odeint import TABLEAUX, odeint_grid, odeint_grid_traj

ORDERS = {"euler": 1, "midpoint": 2, "heun2": 2, "bosh3": 3, "rk4": 4}


@pytest.mark.parametrize("method,order", ORDERS.items())
def test_polynomial_exactness(method, order):
    """An order-m RK method integrates dz/dt = p(t) exactly for
    deg p <= m-1 (quadrature view of the tableau)."""
    coeffs = np.arange(1, order + 1, dtype=np.float32)  # degree order-1

    def f(z, t):
        return jnp.polyval(jnp.asarray(coeffs), t) * jnp.ones_like(z)

    z0 = jnp.zeros((1,), jnp.float32)
    got = odeint_grid(f, z0, 0.0, 1.0, steps=3, method=method)
    anti = np.polyint(coeffs)
    want = np.polyval(anti, 1.0) - np.polyval(anti, 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("method,order", [("euler", 1), ("midpoint", 2),
                                          ("bosh3", 3), ("rk4", 4)])
def test_convergence_order(method, order):
    """Error on dz/dt = z shrinks like h^order."""
    z0 = jnp.ones((1,), jnp.float32)
    f = lambda z, t: z
    errs = []
    for steps in (8, 16):
        zT = odeint_grid(f, z0, 0.0, 1.0, steps=steps, method=method)
        errs.append(abs(float(zT[0]) - np.e))
    rate = np.log2(errs[0] / errs[1])
    assert rate > order - 0.6, f"{method}: observed rate {rate}"


def test_traj_shape_and_consistency():
    f = lambda z, t: -z
    z0 = jnp.ones((4,), jnp.float32)
    zT, traj = odeint_grid_traj(f, z0, 0.0, 1.0, steps=10)
    assert traj.shape == (10, 4)
    np.testing.assert_allclose(traj[-1], zT)
    np.testing.assert_allclose(zT, np.exp(-1.0), rtol=1e-4)


def test_tableau_consistency():
    """Every tableau satisfies sum(b) = 1 and row-sum(a_i) = c_{i+1}."""
    for name, (a, b, c) in TABLEAUX.items():
        assert abs(sum(b) - 1.0) < 1e-12, name
        for i, row in enumerate(a):
            assert abs(sum(row) - c[i + 1]) < 1e-12, f"{name} row {i}"


def test_pytree_state():
    f = lambda s, t: (s[1], -s[0])  # harmonic oscillator as a tuple state
    s0 = (jnp.ones(()), jnp.zeros(()))
    x, v = odeint_grid(f, s0, 0.0, np.pi / 2, steps=64, method="rk4")
    np.testing.assert_allclose(float(x), 0.0, atol=1e-4)
    np.testing.assert_allclose(float(v), -1.0, atol=1e-4)
