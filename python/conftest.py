"""Make `import compile...` work regardless of pytest's invocation cwd
(repo root or python/)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
